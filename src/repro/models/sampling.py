"""Replayable stochastic sampling: counter-based per-request RNG.

The serving stack's migration (§4.3), vLLM-style recompute preemption, and
``fork_stream`` hand-offs are lossless only if regenerating a token always
reproduces it. Greedy argmax gives that for free; temperature sampling needs
the RNG itself to be replayable. The rule here: the token at absolute
position ``i`` of a request is a **pure function of (request_key, i,
logits)** — the per-token key is ``fold_in(request_key, i)``, never a
sequentially split stream. That makes sampling independent of

* **chunking** — a ``decode_n`` scan of 8 steps and 8 single steps fold the
  same positions;
* **batch composition** — every row carries its own key, so admissions,
  cancellations, and frozen rows elsewhere in the batch change nothing (a
  frozen row derives a key it discards — no randomness is "consumed" from
  any stream);
* **replay path** — a migration target or preemption resume re-prefilling
  prompt + already-emitted tokens lands on the same position counter and
  continues with bit-identical draws.

Position convention: a token's position is the number of context tokens
that precede it — the prefill of an S-token prompt samples its first token
at position S; a decode step whose cache holds ``lengths`` tokens (input
token included) samples at position ``lengths``.

Per-row runtime operands: :class:`SamplerConfig` is the *per-request* spec;
the serving engines stack a batch of them into :class:`SamplerOperands` —
``(B,)`` temperature/top-k/top-p arrays that ride through the jitted step
functions as regular traced arguments (``sampler_operands``). Nothing about
the sampler is closed over by a jit anymore, so heterogeneous configs
(greedy next to temperature/top-p next to top-k) coexist in ONE batch and a
request's draws are bit-identical whether it runs alone, in any batch
composition, after recompute preemption, or across a migration replay.
Greedy is the ``temperature == 0`` branch of the same per-row math (exact
argmax — a greedy row's discarded draw consumes no randomness).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SamplerConfig",
    "SamplerOperands",
    "GREEDY",
    "request_key",
    "sampler_operands",
    "sample_tokens",
    "sampling_probs",
    "speculative_accept",
    "first_rejection",
    "mask_top_k",
    "mask_top_p",
]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """How next-token logits become a token.

    ``temperature == 0`` is exact greedy argmax (no RNG touched at all).
    ``top_k`` / ``top_p`` restrict the candidate set before the categorical
    draw (0 / 1.0 disable them). The config is *per request*: serving
    engines stack one per batch row into :class:`SamplerOperands` and pass
    them through the jitted step functions as runtime arrays alongside the
    per-request keys — nothing here is baked into a jit closure.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0 (got {self.temperature})")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (got {self.top_k})")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1] (got {self.top_p})")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplerConfig()


class SamplerOperands(NamedTuple):
    """Per-row sampler parameters as ``(B,)`` runtime arrays — the traced
    twin of a batch of :class:`SamplerConfig`. Rows with ``temperature <= 0``
    take the exact greedy-argmax branch; ``top_k <= 0`` / ``top_p >= 1``
    disable the respective mask per row."""

    temperature: jnp.ndarray    # (B,) float32
    top_k: jnp.ndarray          # (B,) int32
    top_p: jnp.ndarray          # (B,) float32


def sampler_operands(samplers: Sequence[Optional[SamplerConfig]],
                     batch: Optional[int] = None) -> SamplerOperands:
    """Stack per-request configs into (B,) host arrays (``None`` rows are
    greedy). ``batch`` right-pads with greedy rows to a fixed batch size
    (continuous-batching servers keep free rows greedy-frozen)."""
    n = len(samplers) if batch is None else int(batch)
    temp = np.zeros((n,), np.float32)
    top_k = np.zeros((n,), np.int32)
    top_p = np.ones((n,), np.float32)
    for i, s in enumerate(samplers):
        if s is None:
            continue
        temp[i] = s.temperature
        top_k[i] = s.top_k
        top_p[i] = s.top_p
    return SamplerOperands(temp, top_k, top_p)


def request_key(seed: int) -> jax.Array:
    """The per-request base key ((2,) uint32). Every token of the request is
    drawn with ``fold_in(request_key(seed), position)``, so two streams with
    the same seed are interchangeable mid-generation — the property the
    consistent-prefix hand-off and recompute preemption rely on."""
    return jax.random.PRNGKey(seed)


def mask_top_k(logits: jnp.ndarray, k) -> jnp.ndarray:
    """Keep the ``k`` largest logits per row, -inf the rest (ties at the
    k-th value are all kept). ``k <= 0`` or ``k >= vocab`` is a no-op.

    ``k`` may be a static python int (one config for the whole batch) or a
    per-row ``(B,)`` int array — heterogeneous batches use the latter.
    """
    vocab = logits.shape[-1]
    if isinstance(k, (int, np.integer)):
        if k <= 0 or k >= vocab:
            return logits
        thresh = jax.lax.top_k(logits, int(k))[0][..., -1:]
        return jnp.where(logits < thresh, -jnp.inf, logits)
    k = jnp.asarray(k, jnp.int32)
    sort = jnp.sort(logits, axis=-1)[..., ::-1]
    # threshold = the k-th largest value per row (same rule as lax.top_k)
    idx = jnp.clip(k - 1, 0, vocab - 1)[:, None]
    thresh = jnp.take_along_axis(sort, idx, axis=-1)
    masked = jnp.where(logits < thresh, -jnp.inf, logits)
    disabled = ((k <= 0) | (k >= vocab))[:, None]
    return jnp.where(disabled, logits, masked)


def mask_top_p(logits: jnp.ndarray, p) -> jnp.ndarray:
    """Nucleus mask: keep the smallest probability-sorted prefix whose
    cumulative probability reaches ``p`` (the argmax always survives, so
    ``p -> 0`` degrades to greedy, never to an empty support).

    ``p`` may be a static python float or a per-row ``(B,)`` array; the
    exclusive-cumsum rule makes ``p >= 1`` a natural per-row no-op (every
    token's preceding mass is < 1).
    """
    if isinstance(p, (int, float)) and p >= 1.0:
        return logits
    p_col = p if isinstance(p, (int, float)) else jnp.asarray(p)[:, None]
    sort = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sort, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p_col        # exclusive cumsum: top-1 always kept
    thresh = jnp.min(jnp.where(keep, sort, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(logits < thresh, -jnp.inf, logits)


def _draw(key, pos, row_logits):
    return jax.random.categorical(jax.random.fold_in(key, pos), row_logits)


def _mask_top_k_p_rows(scaled: jnp.ndarray, top_k: jnp.ndarray,
                       top_p: jnp.ndarray) -> jnp.ndarray:
    """Per-row top-k THEN top-p masking sharing ONE descending sort (the
    serving hot path runs this inside the fused decode scan; two separate
    sorts of the same array would double the dominant sampling cost).
    Bit-equivalent to ``mask_top_p(mask_top_k(scaled, top_k), top_p)``:
    value-thresholding keeps the sorted order of survivors intact, so the
    top-p pass can reuse the top-k-masked sorted array directly."""
    vocab = scaled.shape[-1]
    sort = jnp.sort(scaled, axis=-1)[..., ::-1]
    # top-k threshold = k-th largest per row (ties at the threshold kept);
    # disabled rows (k<=0 or k>=V) threshold at -inf
    idx = jnp.clip(top_k - 1, 0, vocab - 1)[:, None]
    k_thresh = jnp.take_along_axis(sort, idx, axis=-1)
    k_disabled = ((top_k <= 0) | (top_k >= vocab))[:, None]
    k_thresh = jnp.where(k_disabled, -jnp.inf, k_thresh)
    sort_k = jnp.where(sort < k_thresh, -jnp.inf, sort)
    # nucleus threshold over the top-k survivors (exclusive cumsum: top-1
    # always kept; p >= 1 keeps every survivor)
    probs = jax.nn.softmax(sort_k, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]
    p_thresh = jnp.min(jnp.where(keep, sort_k, jnp.inf), axis=-1, keepdims=True)
    thresh = jnp.maximum(k_thresh, p_thresh)
    return jnp.where(scaled < thresh, -jnp.inf, scaled)


def sample_tokens(
    sampler,                  # None | SamplerConfig | SamplerOperands
    logits: jnp.ndarray,      # (B, V) f32 next-token logits
    keys: Optional[jnp.ndarray],    # (B, 2) uint32 per-request base keys
    positions: Optional[jnp.ndarray],  # (B,) int32 absolute token positions
    *,
    return_probs: bool = False,
):
    """Sample one token per row: ``fold_in(key, position)`` -> masked
    categorical. Pure in (key, position, logits); jit/vmap/scan-safe.
    Returns (B,) int32.

    ``sampler`` is either a single :class:`SamplerConfig` applied to every
    row (``None`` or temperature 0 is exact greedy argmax and ignores
    ``keys``/``positions``, which may then be None), or per-row
    :class:`SamplerOperands` — the serving path, where every row carries its
    own temperature/top-k/top-p and greedy is the ``temperature <= 0``
    branch of the same math (exact argmax per row). Each row's result
    depends only on its own (config, key, position, logits), so a request
    draws identical tokens alone or inside any batch composition.

    ``return_probs=True`` additionally returns the per-row post-mask
    sampling distribution (``(B, V)`` float32, one-hot for greedy rows) as
    ``(tokens, probs)``. Only the speculative draft/verify paths opt in:
    the default call keeps the all-greedy ``lax.cond`` fast path below
    untouched, while the probs variant computes the masked distribution
    unconditionally (the distribution of a greedy row is its argmax
    one-hot, which the cond cannot shortcut).
    """
    if return_probs:
        tokens = sample_tokens(sampler, logits, keys, positions)
        return tokens, sampling_probs(sampler, logits)
    if isinstance(sampler, SamplerOperands):
        if keys is None or positions is None:
            raise ValueError(
                "stochastic sampling (temperature > 0) requires per-row keys "
                "and absolute positions"
            )
        positions = jnp.asarray(positions, jnp.int32)
        temp = jnp.asarray(sampler.temperature, jnp.float32)
        greedy_rows = temp <= 0.0
        argm = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def stochastic(_):
            safe_t = jnp.where(greedy_rows, 1.0, temp)
            scaled = logits.astype(jnp.float32) / safe_t[:, None]
            scaled = _mask_top_k_p_rows(
                scaled, jnp.asarray(sampler.top_k, jnp.int32),
                jnp.asarray(sampler.top_p, jnp.float32),
            )
            drawn = jax.vmap(_draw)(keys, positions, scaled).astype(jnp.int32)
            return jnp.where(greedy_rows, argm, drawn)

        # all-greedy batches skip the sort/mask work at runtime entirely —
        # the decode hot path pays nothing for the per-row sampler plumbing
        return jax.lax.cond(
            jnp.any(temp > 0.0), stochastic, lambda _: argm, None
        )
    if sampler is None or sampler.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if keys is None or positions is None:
        raise ValueError(
            "stochastic sampling (temperature > 0) requires per-row keys "
            "and absolute positions"
        )
    scaled = logits.astype(jnp.float32) / sampler.temperature
    scaled = mask_top_k(scaled, sampler.top_k)
    scaled = mask_top_p(scaled, sampler.top_p)
    positions = jnp.asarray(positions, jnp.int32)
    return jax.vmap(_draw)(keys, positions, scaled).astype(jnp.int32)


def sampling_probs(
    sampler,                  # None | SamplerConfig | SamplerOperands
    logits: jnp.ndarray,      # (B, V) f32 next-token logits
) -> jnp.ndarray:
    """The per-row next-token distribution that :func:`sample_tokens` draws
    from: temperature-scaled, top-k/top-p-masked softmax, and an exact
    argmax one-hot for greedy rows (``temperature <= 0``). Returns (B, V)
    float32 rows summing to 1.

    This is the probability surface speculative decoding verifies against —
    ``categorical(fold_in(key, pos), log(probs))`` reproduces the exact
    token :func:`sample_tokens` emits for the same row, so acceptance ratios
    computed from these rows are faithful to the serving sampler, masks
    included. Deliberately NOT behind the all-greedy ``lax.cond`` fast path:
    a greedy row still has a (one-hot) distribution to report, so callers
    that want probs always pay for them — which is why the default decode
    path never calls this.
    """
    vocab = logits.shape[-1]
    argm = jnp.argmax(logits, axis=-1)
    one_hot = jax.nn.one_hot(argm, vocab, dtype=jnp.float32)
    if isinstance(sampler, SamplerOperands):
        temp = jnp.asarray(sampler.temperature, jnp.float32)
        greedy_rows = temp <= 0.0
        safe_t = jnp.where(greedy_rows, 1.0, temp)
        scaled = logits.astype(jnp.float32) / safe_t[:, None]
        scaled = _mask_top_k_p_rows(
            scaled, jnp.asarray(sampler.top_k, jnp.int32),
            jnp.asarray(sampler.top_p, jnp.float32),
        )
        probs = jax.nn.softmax(scaled, axis=-1)
        return jnp.where(greedy_rows[:, None], one_hot, probs)
    if sampler is None or sampler.greedy:
        return one_hot
    scaled = logits.astype(jnp.float32) / sampler.temperature
    scaled = mask_top_k(scaled, sampler.top_k)
    scaled = mask_top_p(scaled, sampler.top_p)
    return jax.nn.softmax(scaled, axis=-1)


# Salts separating the speculative accept-coin and residual-resample RNG
# streams from the token-draw stream. Token i of a request is ALWAYS
# ``categorical(fold_in(key, i), ...)`` — the salted draws below fold the
# salt in first, so running speculative rounds consumes no randomness from
# the token stream and the accepted prefix stays bit-identical to what the
# server alone would have drawn at the same positions.
_ACCEPT_SALT = 0x5BD1E995
_RESIDUAL_SALT = 0x27D4EB2F


def _accept_coin(key, pos):
    return jax.random.uniform(
        jax.random.fold_in(jax.random.fold_in(key, _ACCEPT_SALT), pos)
    )


def _residual_draw(key, pos, row_probs):
    # categorical is shift-invariant in log space, so the unnormalized
    # residual works directly; zero-probability entries mask to -inf
    return jax.random.categorical(
        jax.random.fold_in(jax.random.fold_in(key, _RESIDUAL_SALT), pos),
        jnp.log(row_probs),
    )


def speculative_accept(
    key: jnp.ndarray,           # (2,) uint32 request base key
    positions: jnp.ndarray,     # (k,) int32 absolute positions of the drafts
    draft: jnp.ndarray,         # (k,) int32 device draft tokens
    device_probs: jnp.ndarray,  # (k, V) device sampling distributions
    server_probs: jnp.ndarray,  # (k, V) server sampling distributions
):
    """Lossless rejection-sampling verdict for one request's draft window.

    Draft token ``d_i`` is accepted with probability
    ``min(1, p_server(d_i) / p_device(d_i))`` — the accept coin is
    ``uniform(fold_in(fold_in(key, salt), position))``, pure in (key,
    position), so verdicts replay bit-identically. On rejection the
    correction token is drawn from the normalized residual
    ``max(p_server - p_device, 0)``; together the two cases emit tokens
    distributed EXACTLY as the server sampler — speculative decoding
    changes wall-clock, never the output distribution (Leviathan et al.,
    and the P/D-Device device-draft setting of PAPERS.md).

    Returns ``(accept, corrections)`` — (k,) bool per-position verdicts and
    (k,) int32 residual draws. The caller scans ``accept`` for the first
    ``False``: drafts before it are delivered, the correction at that index
    replaces the rejected draft, everything after is discarded (the
    verdicts/corrections past the first rejection are conditioned on a
    prefix that no longer exists and MUST not be used).

    At matched draft/verify models ``p_device == p_server`` row-wise, every
    coin passes (``u * p <= p``), and the drafts themselves are the server's
    own ``fold_in(key, pos)`` categorical draws — so the delivered stream is
    bit-identical to same-seed server-only generation.

    Degenerate residual (``p_server == p_device`` within float tolerance,
    e.g. two greedy one-hots): falls back to drawing from ``server_probs``
    itself, which is the correct limit of the residual as mass -> 0.
    """
    positions = jnp.asarray(positions, jnp.int32)
    draft = jnp.asarray(draft, jnp.int32)
    p_d = jnp.take_along_axis(device_probs, draft[:, None], axis=-1)[:, 0]
    p_s = jnp.take_along_axis(server_probs, draft[:, None], axis=-1)[:, 0]
    u = jax.vmap(_accept_coin, in_axes=(None, 0))(key, positions)
    # strict guard on p_s == 0: u can be exactly 0.0, and a zero-server-prob
    # token must never be accepted
    accept = (u * p_d <= p_s) & (p_s > 0.0)
    residual = jnp.clip(server_probs - device_probs, 0.0, None)
    mass = jnp.sum(residual, axis=-1, keepdims=True)
    residual = jnp.where(mass > 1e-9, residual, server_probs)
    corrections = jax.vmap(_residual_draw, in_axes=(None, 0, 0))(
        key, positions, residual
    ).astype(jnp.int32)
    return accept, corrections


def first_rejection(accept: jnp.ndarray) -> jnp.ndarray:
    """Index of the first ``False`` along the last axis — the number of
    accepted drafts — or ``k`` when the whole window is accepted. Works on
    a single (k,) verdict vector or a batched (B, k) stack."""
    k = accept.shape[-1]
    rej = jnp.argmax(~accept, axis=-1)
    return jnp.where(jnp.all(accept, axis=-1), k, rej).astype(jnp.int32)
