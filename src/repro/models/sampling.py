"""Replayable stochastic sampling: counter-based per-request RNG.

The serving stack's migration (§4.3), vLLM-style recompute preemption, and
``fork_stream`` hand-offs are lossless only if regenerating a token always
reproduces it. Greedy argmax gives that for free; temperature sampling needs
the RNG itself to be replayable. The rule here: the token at absolute
position ``i`` of a request is a **pure function of (request_key, i,
logits)** — the per-token key is ``fold_in(request_key, i)``, never a
sequentially split stream. That makes sampling independent of

* **chunking** — a ``decode_n`` scan of 8 steps and 8 single steps fold the
  same positions;
* **batch composition** — every row carries its own key, so admissions,
  cancellations, and frozen rows elsewhere in the batch change nothing (a
  frozen row derives a key it discards — no randomness is "consumed" from
  any stream);
* **replay path** — a migration target or preemption resume re-prefilling
  prompt + already-emitted tokens lands on the same position counter and
  continues with bit-identical draws.

Position convention: a token's position is the number of context tokens
that precede it — the prefill of an S-token prompt samples its first token
at position S; a decode step whose cache holds ``lengths`` tokens (input
token included) samples at position ``lengths``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "SamplerConfig",
    "GREEDY",
    "request_key",
    "sample_tokens",
    "mask_top_k",
    "mask_top_p",
]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """How next-token logits become a token.

    ``temperature == 0`` is exact greedy argmax (no RNG touched at all).
    ``top_k`` / ``top_p`` restrict the candidate set before the categorical
    draw (0 / 1.0 disable them). The config is static per engine — it is
    closed over by the jitted step functions — while the per-request key
    rides in as a regular traced argument.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0 (got {self.temperature})")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (got {self.top_k})")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1] (got {self.top_p})")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplerConfig()


def request_key(seed: int) -> jax.Array:
    """The per-request base key ((2,) uint32). Every token of the request is
    drawn with ``fold_in(request_key(seed), position)``, so two streams with
    the same seed are interchangeable mid-generation — the property the
    consistent-prefix hand-off and recompute preemption rely on."""
    return jax.random.PRNGKey(seed)


def mask_top_k(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the ``k`` largest logits per row, -inf the rest (ties at the
    k-th value are all kept). ``k <= 0`` or ``k >= vocab`` is a no-op."""
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    thresh = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < thresh, -jnp.inf, logits)


def mask_top_p(logits: jnp.ndarray, p: float) -> jnp.ndarray:
    """Nucleus mask: keep the smallest probability-sorted prefix whose
    cumulative probability reaches ``p`` (the argmax always survives, so
    ``p -> 0`` degrades to greedy, never to an empty support)."""
    if p >= 1.0:
        return logits
    sort = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sort, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p            # exclusive cumsum: top-1 always kept
    thresh = jnp.min(jnp.where(keep, sort, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(logits < thresh, -jnp.inf, logits)


def sample_tokens(
    sampler: Optional[SamplerConfig],
    logits: jnp.ndarray,      # (B, V) f32 next-token logits
    keys: Optional[jnp.ndarray],    # (B, 2) uint32 per-request base keys
    positions: Optional[jnp.ndarray],  # (B,) int32 absolute token positions
) -> jnp.ndarray:
    """Sample one token per row: ``fold_in(key, position)`` -> masked
    categorical. Pure in (key, position, logits); jit/vmap/scan-safe.

    ``sampler=None`` or temperature 0 is exact greedy argmax and ignores
    ``keys``/``positions`` entirely (they may be None). Returns (B,) int32.
    """
    if sampler is None or sampler.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if keys is None or positions is None:
        raise ValueError(
            "stochastic sampling (temperature > 0) requires per-row keys "
            "and absolute positions"
        )
    scaled = logits.astype(jnp.float32) / sampler.temperature
    scaled = mask_top_k(scaled, sampler.top_k)
    scaled = mask_top_p(scaled, sampler.top_p)

    def draw(key, pos, row_logits):
        return jax.random.categorical(jax.random.fold_in(key, pos), row_logits)

    positions = jnp.asarray(positions, jnp.int32)
    return jax.vmap(draw)(keys, positions, scaled).astype(jnp.int32)
