"""Rotary position embeddings (applied on the fly — positions up to 512k)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope"]


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions``
    of shape broadcastable to (..., seq). Computed in float32, cast back."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    angles = angles[..., None, :]                             # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)
