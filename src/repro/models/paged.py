"""Paged-KV decode path: model step functions over a shared block pool.

Physical KV storage is a pool of fixed-size token blocks ``(L, N, K, bs, D)``
shared by every request; each batch row addresses its sequence through a
``(B, MB)`` page table (``repro.serving.kv_pool`` owns the host-side
allocation; block 0 is the reserved NULL/trash block). This decouples memory
from batch rows: a 32-token reply holds 2–3 blocks while a 2k-token one
holds 128, instead of both reserving a dense ``max_len`` row.

Three step functions mirror the dense trio in ``model.py``:

  paged_prefill(params, cfg, pages, tokens, lengths, block_ids)
  paged_decode_step(params, cfg, pages, block_tables, lengths, token, ...)
  paged_decode_n(...)    # fused scan of paged_decode_step

Unlike the dense cache, ``lengths``/page tables are *caller-owned* (host
side): they ride in as arguments per dispatch and the advanced lengths ride
back out, so the pool arrays are the only donated device state and many
independent requests can share them safely.

Attention reads go through ``paged_gather_kv`` (XLA gather — the production
CPU path) or the Pallas ``paged_decode_attention`` kernel (TPU: the page
table becomes the DMA index map, no materialized gather). Only causal
attention-only token models are supported — SSM state is per-row (nothing to
page) and MLA's compressed cache needs its own block shape; those fall back
to the dense cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_decode_attention import (
    paged_decode_attention,
    paged_gather_kv,
)

from .attention import attention, decode_attention
from .config import ModelConfig
from .layers import _qkv, ffn_apply, rms_norm
from .model import Cache, _embed, _logits, prefill, window_vector
from .rope import apply_rope
from .sampling import (
    first_rejection,
    sample_tokens,
    sampling_probs,
    speculative_accept,
)

__all__ = [
    "supports_paged",
    "init_paged_pages",
    "paged_prefill",
    "paged_suffix_prefill",
    "paged_piece_prefill",
    "paged_decode_step",
    "paged_decode_n",
    "paged_draft_n",
    "paged_verify_n",
    "NULL_BLOCK",
]

NULL_BLOCK = 0     # reserved trash block: page-table padding + frozen-row
                   # writes land here (serving.kv_pool re-exports this —
                   # the allocator never hands block 0 to a request)


def supports_paged(cfg: ModelConfig) -> bool:
    """Paged KV is sound for causal attention-only token models: recurrent
    SSM state is per-row (not paged) and MLA caches compressed latents with
    a different block shape; encoders have no decode path at all."""
    return (
        cfg.has_attention
        and not cfg.use_mla
        and not cfg.has_ssm
        and cfg.causal
        and cfg.embed_inputs
        and not cfg.is_encoder
    )


def init_paged_pages(cfg: ModelConfig, num_blocks: int, block_size: int) -> Cache:
    """Zero-initialized block pool: {"k","v"} of (L, N, K, bs, D)."""
    if not supports_paged(cfg):
        raise ValueError(f"{cfg.name}: paged KV unsupported for this architecture")
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, num_blocks, cfg.n_kv_heads, block_size, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def paged_prefill(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    tokens: jnp.ndarray,      # (1, S) bucket-padded, S % block_size == 0
    lengths: jnp.ndarray,     # (1,) true prompt length
    block_ids: jnp.ndarray,   # (S // block_size,) physical blocks for the prompt
    *,
    sampler=None,    # SamplerConfig | SamplerOperands (per-row runtime arrays)
    keys: Optional[jnp.ndarray] = None,    # (1, 2) uint32 request key
):
    """Alloc-on-prefill write path: run the dense prefill math for one row
    and scatter its K/V into the request's blocks (one (nb,)-indexed scatter
    per pool array — whole blocks move, not tokens). Pad-tail positions land
    in the tail block and are masked by ``lengths`` at read time.

    The first token is sampled at absolute position ``lengths`` (the true
    prompt length), so a replay prefill of prompt + delivered tokens lands
    on the same position counter the source's decode would use next.

    Returns (first_token (1,) int32, pages).
    """
    s = tokens.shape[1]
    bs = pages["k"].shape[3]
    assert s % bs == 0, (s, bs)
    nb = s // bs
    assert block_ids.shape[0] == nb, (block_ids.shape, nb)
    last, cache = prefill(params, cfg, tokens, s, lengths=lengths)
    new_pages = dict(pages)
    for key in ("k", "v"):
        arr = cache[key][:, 0]                       # (L, K, S, D) head-major
        l, kh, _, d = arr.shape
        blocks = arr.reshape(l, kh, nb, bs, d).transpose(0, 2, 1, 3, 4)
        new_pages[key] = pages[key].at[:, block_ids].set(
            blocks.astype(pages[key].dtype)
        )
    return sample_tokens(sampler, last, keys, lengths), new_pages


def paged_suffix_prefill(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    tokens: jnp.ndarray,      # (1, S') suffix slice of the padded prompt
    lengths: jnp.ndarray,     # (1,) true TOTAL prompt length (prefix + suffix)
    prefix_bt: jnp.ndarray,   # (1, NP) cached prefix blocks (no NULL padding)
    block_ids: jnp.ndarray,   # (S' // block_size,) physical suffix blocks
    *,
    sampler=None,    # SamplerConfig | SamplerOperands (per-row runtime arrays)
    keys: Optional[jnp.ndarray] = None,    # (1, 2) uint32 request key
):
    """Prefix-hit write path: the first ``NP`` blocks of the prompt are
    already sealed in the pool (a radix prefix-index hit), so only the
    unmatched suffix is computed. Per layer, the suffix queries — at
    absolute positions ``NP*bs + arange(S')`` — attend over the gathered
    prefix K/V concatenated with the freshly computed suffix K/V; the key
    axis then has exactly the bucket layout (same length, same values at the
    same indices) the cold full prefill would reduce over, which is what
    keeps prefix-hit streams bitwise-identical to cold-cache runs. Only the
    suffix blocks are scattered; the prefix blocks are read-only aliases.

    The first token is sampled at absolute position ``lengths`` exactly as
    the cold path does (the last real position is never part of the matched
    prefix — ``KVPoolManager.prefix_match`` caps the match one block short).

    Returns (first_token (1,) int32, pages).
    """
    s2 = tokens.shape[1]
    bs = pages["k"].shape[3]
    assert s2 % bs == 0 and s2 > 0, (s2, bs)
    nb = s2 // bs
    assert block_ids.shape[0] == nb, (block_ids.shape, nb)
    n_pre = prefix_bt.shape[1] * bs        # static: shapes key the jit cache
    positions = n_pre + jnp.arange(s2)
    h0 = _embed(params, cfg, tokens)

    def body(x, xs):
        lp, window, pg = xs                # pg: per-layer (N, K, bs, D)
        h = rms_norm(x, lp["mixer_norm"])
        q, k, v = _qkv(cfg, lp, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # (1, K, NP*bs, D) head-major -> (1, NP*bs, K, D) seq-major
        kp = paged_gather_kv(pg["k"], prefix_bt).transpose(0, 2, 1, 3)
        vp = paged_gather_kv(pg["v"], prefix_bt).transpose(0, 2, 1, 3)
        k_full = jnp.concatenate([kp.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([vp.astype(v.dtype), v], axis=1)
        o = attention(
            q, k_full, v_full, causal=cfg.causal, window=window, q_offset=n_pre
        )
        out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        x = x + out.astype(x.dtype)
        if cfg.has_ffn:
            f, _ = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
        return x, {"k": k, "v": v}

    h, kv = jax.lax.scan(
        body, h0, (params["layers"], window_vector(cfg), pages)
    )
    idx = jnp.clip(lengths - 1 - n_pre, 0, s2 - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)   # (1,1,d)
    last = _logits(params, cfg, h_last)[:, 0]
    new_pages = dict(pages)
    for key in ("k", "v"):
        arr = kv[key][:, 0]                          # (L, S', K, D)
        l, _, kh, d = arr.shape
        blocks = arr.reshape(l, nb, bs, kh, d).transpose(0, 1, 3, 2, 4)
        new_pages[key] = pages[key].at[:, block_ids].set(
            blocks.astype(pages[key].dtype)
        )
    return sample_tokens(sampler, last, keys, lengths), new_pages


def paged_piece_prefill(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    tokens: jnp.ndarray,      # (1, P) one piece of the bucket-padded prompt
    lengths: jnp.ndarray,     # (1,) true TOTAL prompt length
    full_bt: jnp.ndarray,     # (1, NB) ALL reserved blocks of the bucket
    n_pre: jnp.ndarray,       # () int32 tokens already written — TRACED
    block_ids: jnp.ndarray,   # (P // block_size,) physical blocks of the piece
    *,
    sampler=None,    # SamplerConfig | SamplerOperands (per-row runtime arrays)
    keys: Optional[jnp.ndarray] = None,    # (1, 2) uint32 request key
):
    """Chunked (piecewise) prefill: one token-budget-bounded piece of a
    prompt whose blocks are ALL reserved up front. Unlike
    ``paged_suffix_prefill`` (static prefix length — shapes key the jit
    cache per hit size), the already-written length ``n_pre`` rides in as a
    *traced* operand, so every piece of a bucket shares one compiled
    dispatch keyed only by (bucket length, piece length).

    Per layer the piece queries — at absolute positions
    ``n_pre + arange(P)`` — attend over the whole gathered bucket K/V with
    the fresh piece K/V spliced in at ``n_pre`` (``dynamic_update_slice``).
    The key axis therefore has exactly the bucket layout the monolithic
    prefill reduces over: positions below ``n_pre`` hold earlier pieces'
    sealed K/V (bitwise what the monolithic run computed there, by
    induction), and positions at or above ``n_pre + P`` hold garbage that
    the causal mask zeroes *exactly* (the −1e30 bias rounds the logit to
    −1e30 in f32 and exp underflows to 0.0 — the same invariant the
    prefix-hit path relies on), so piecewise logits are bitwise-identical
    to the whole-prompt prefill. Only the piece's blocks are scattered.

    The sampled token is meaningful only on the final piece (position
    ``lengths`` falls inside it); earlier pieces sample a clamped position
    and the caller discards the result. The position-keyed sampler draws at
    the same absolute position either way, so no randomness is consumed.

    Returns (token (1,) int32, pages).
    """
    s2 = tokens.shape[1]
    bs = pages["k"].shape[3]
    assert s2 % bs == 0 and s2 > 0, (s2, bs)
    nb = s2 // bs
    assert block_ids.shape[0] == nb, (block_ids.shape, nb)
    n_pre = jnp.asarray(n_pre, jnp.int32)
    positions = n_pre + jnp.arange(s2)
    h0 = _embed(params, cfg, tokens)

    def body(x, xs):
        lp, window, pg = xs                # pg: per-layer (N, K, bs, D)
        h = rms_norm(x, lp["mixer_norm"])
        q, k, v = _qkv(cfg, lp, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # (1, K, S, D) head-major -> (1, S, K, D) seq-major, S = bucket len
        kc = paged_gather_kv(pg["k"], full_bt).transpose(0, 2, 1, 3)
        vc = paged_gather_kv(pg["v"], full_bt).transpose(0, 2, 1, 3)
        k_full = jax.lax.dynamic_update_slice(
            kc.astype(k.dtype), k, (0, n_pre, 0, 0)
        )
        v_full = jax.lax.dynamic_update_slice(
            vc.astype(v.dtype), v, (0, n_pre, 0, 0)
        )
        o = attention(
            q, k_full, v_full, causal=cfg.causal, window=window, q_offset=n_pre
        )
        out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        x = x + out.astype(x.dtype)
        if cfg.has_ffn:
            f, _ = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
        return x, {"k": k, "v": v}

    h, kv = jax.lax.scan(
        body, h0, (params["layers"], window_vector(cfg), pages)
    )
    idx = jnp.clip(lengths - 1 - n_pre, 0, s2 - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)   # (1,1,d)
    last = _logits(params, cfg, h_last)[:, 0]
    new_pages = dict(pages)
    for key in ("k", "v"):
        arr = kv[key][:, 0]                          # (L, P, K, D)
        l, _, kh, d = arr.shape
        blocks = arr.reshape(l, nb, bs, kh, d).transpose(0, 1, 3, 2, 4)
        new_pages[key] = pages[key].at[:, block_ids].set(
            blocks.astype(pages[key].dtype)
        )
    return sample_tokens(sampler, last, keys, lengths), new_pages


def _write_targets(block_tables, new_lengths, ok, block_size):
    """(physical block, in-block offset) of each row's next KV write. Frozen
    rows (``ok`` False) are routed to the NULL/trash block so the shared
    scatter never clobbers live data."""
    pos = new_lengths - 1
    mb = block_tables.shape[1]
    slot = jnp.clip(pos // block_size, 0, mb - 1)
    wb = jnp.take_along_axis(block_tables, slot[:, None], axis=1)[:, 0]
    wb = jnp.where(ok, wb, NULL_BLOCK)
    wo = jnp.where(ok, pos % block_size, 0)
    return wb, wo


def _paged_decode_layer_body(cfg, lengths, block_tables, wb, wo, use_kernel):
    def body(x, xs):
        lp, window, pg = xs                        # pg: per-layer (N,K,bs,D)
        h = rms_norm(x, lp["mixer_norm"])
        q, k, v = _qkv(cfg, lp, h)
        pos = (lengths - 1)[:, None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        # scatter the single new K/V per row into (block, offset)
        k_pages = pg["k"].at[wb, :, wo, :].set(k[:, 0].astype(pg["k"].dtype))
        v_pages = pg["v"].at[wb, :, wo, :].set(v[:, 0].astype(pg["v"].dtype))
        if use_kernel:
            # page table as DMA index map (TPU); window statically 0 —
            # paged_decode_n rejects windowed configs on this path
            o = paged_decode_attention(
                q[:, 0], k_pages, v_pages, block_tables, lengths
            )
        else:
            k_seq = paged_gather_kv(k_pages, block_tables)
            v_seq = paged_gather_kv(v_pages, block_tables)
            o = decode_attention(q[:, 0], k_seq, v_seq, lengths, window=window)
        out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
        x = x + out.astype(x.dtype)
        if cfg.has_ffn:
            f, _ = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
        return x, {"k": k_pages, "v": v_pages}

    return body


def paged_decode_step(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    block_tables: jnp.ndarray,   # (B, MB) int32, NULL-padded
    lengths: jnp.ndarray,        # (B,) cache entries currently valid
    token: jnp.ndarray,          # (B,) most recent token per row
    *,
    max_len: int,
    active: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
    sampler=None,    # SamplerConfig | SamplerOperands (per-row runtime arrays)
    keys: Optional[jnp.ndarray] = None,    # (B, 2) uint32 request keys
):
    """One paged decode step. Row-freeze semantics match dense ``decode_n``:
    rows stop at ``max_len - 1`` entries and ``active=False`` rows keep
    lengths frozen and re-emit their input token (their write is routed to
    the trash block instead of merged out). The next token is sampled at
    position ``new_lengths`` per row (``models.sampling``); a frozen row's
    position does not advance, so it derives — and discards — the same key
    without consuming randomness from any stream.

    Returns (token_out (B,), logits (B, V) f32, pages, new_lengths).
    """
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    if use_kernel and any(
        cfg.window and not cfg.layer_is_global(i) for i in range(cfg.n_layers)
    ):
        raise ValueError("paged kernel path supports window=0 layers only")
    ok = lengths < (max_len - 1)
    if active is not None:
        ok &= active
    new_lengths = jnp.where(ok, lengths + 1, lengths)
    bs = pages["k"].shape[3]
    wb, wo = _write_targets(block_tables, new_lengths, ok, bs)
    h0 = _embed(params, cfg, token[:, None])
    body = _paged_decode_layer_body(
        cfg, new_lengths, block_tables, wb, wo, use_kernel
    )
    h, new_pages = jax.lax.scan(
        body, h0, (params["layers"], window_vector(cfg), pages)
    )
    logits = _logits(params, cfg, h)[:, 0]
    new_tok = sample_tokens(sampler, logits, keys, new_lengths)
    out_tok = jnp.where(ok, new_tok, token)
    return out_tok, logits, new_pages, new_lengths


def paged_decode_n(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    token: jnp.ndarray,
    num_steps: int,
    *,
    max_len: int,
    active: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
    sampler=None,    # SamplerConfig | SamplerOperands (per-row runtime arrays)
    keys: Optional[jnp.ndarray] = None,
):
    """Fused multi-token paged decode: ``num_steps`` steps under one
    ``lax.scan``, one dispatch per chunk. Callers must have extended each
    row's page table to cover its share of the chunk; steps past a row's
    extension write the NULL-padded table tail (the trash block) and their
    tokens are discarded host-side — same contract as the dense tail
    rounding. ``sampler``/``keys`` select position-keyed sampling exactly as
    in dense ``decode_n`` (greedy when omitted).

    Returns (tokens (num_steps, B) int32, pages, new_lengths).
    """
    def body(carry, _):
        tok, lens, pg = carry
        out_tok, _, pg, lens = paged_decode_step(
            params, cfg, pg, block_tables, lens, tok,
            max_len=max_len, active=active, use_kernel=use_kernel,
            sampler=sampler, keys=keys,
        )
        return (out_tok, lens, pg), out_tok

    (token, lengths, pages), toks = jax.lax.scan(
        body, (token, lengths, pages), None, length=num_steps
    )
    return toks, pages, lengths


def paged_draft_n(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    forced: jnp.ndarray,       # (T, B) int32 teacher-forced inputs
    use_forced: jnp.ndarray,   # (T,) bool — True steps feed forced[i]
    *,
    max_len: int,
    active: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
    sampler=None,
    keys: Optional[jnp.ndarray] = None,
):
    """Paged twin of dense ``model.draft_n``: a fused scan whose step ``i``
    feeds ``forced[i]`` when ``use_forced[i]`` (teacher forcing) and the
    previous sampled token otherwise, emitting the sampled token AND the
    post-mask sampling distribution at every step. All-forced = speculative
    verify; forced-prefix + sampled tail = a device draft window resyncing
    on the last round's correction/bonus token. ``use_forced`` is a runtime
    operand (one compile per T). ``use_forced[0]`` is treated as True.

    Frozen rows (``max_len`` cap, ``active`` mask) keep lengths frozen and
    write the trash block — same contract as ``paged_decode_n``. Rollback to
    an accepted prefix is a host-side lengths/page-table trim; entries past
    ``lengths`` are masked at read time and overwritten in place.

    Returns (toks (T, B) int32, probs (T, B, V) f32, pages, new_lengths).
    """
    forced = jnp.asarray(forced, jnp.int32)
    use_forced = jnp.asarray(use_forced, bool)

    def body(carry, xs):
        tok, lens, pg = carry
        f_tok, f_on = xs
        tok_in = jnp.where(f_on, f_tok, tok)
        out_tok, logits, pg, lens = paged_decode_step(
            params, cfg, pg, block_tables, lens, tok_in,
            max_len=max_len, active=active, use_kernel=use_kernel,
            sampler=sampler, keys=keys,
        )
        return (out_tok, lens, pg), (out_tok, sampling_probs(sampler, logits))

    (_, lengths, pages), (toks, probs) = jax.lax.scan(
        body, (forced[0], lengths, pages), (forced, use_forced)
    )
    return toks, probs, pages, lengths


def paged_verify_n(
    params: dict,
    cfg: ModelConfig,
    pages: Cache,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,        # (B,) cache entries BEFORE the window
    token: jnp.ndarray,          # (B,) last accepted/pending token per row
    draft: jnp.ndarray,          # (k, B) int32 device draft window
    device_probs: jnp.ndarray,   # (k, B, V) device sampling distributions
    *,
    max_len: int,
    active: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
    sampler=None,
    keys: Optional[jnp.ndarray] = None,
):
    """Paged server verify: teacher-force ``[token, draft_1..draft_k]``
    through k+1 fused steps (scratch KV written through the row's page
    table; frozen rows hit the trash block) and run the lossless
    rejection-sampling verdict per row. Same returns as dense
    ``model.verify_n`` — ``(n_acc, accept, corrections, srv_toks, probs,
    pages, new_lengths)`` with ``new_lengths`` advanced k+1; the caller
    rolls back to ``lengths + n_acc + 1`` and releases the scratch blocks
    past the accepted prefix (``KVPoolManager.shrink``).
    """
    draft = jnp.asarray(draft, jnp.int32)
    k = draft.shape[0]
    forced = jnp.concatenate([jnp.asarray(token, jnp.int32)[None], draft], axis=0)
    toks, probs, pages, new_lengths = paged_draft_n(
        params, cfg, pages, block_tables, lengths, forced,
        jnp.ones((k + 1,), bool),
        max_len=max_len, active=active, use_kernel=use_kernel,
        sampler=sampler, keys=keys,
    )
    # draft_i scores position lengths + 1 + i (lengths = pre-window base)
    positions = lengths[:, None] + 1 + jnp.arange(k, dtype=jnp.int32)[None, :]
    accept, corrections = jax.vmap(speculative_accept)(
        keys, positions,
        jnp.swapaxes(draft, 0, 1),
        jnp.swapaxes(device_probs, 0, 1),
        jnp.swapaxes(probs[:k], 0, 1),
    )
    return (
        first_rejection(accept), accept, corrections, toks, probs,
        pages, new_lengths,
    )
