"""Attention primitives: GQA with causal / sliding-window / bidirectional
masking, in two equivalent implementations:

* ``attention_dense`` — materializes (B, H, S, S) scores; fine for short S
  (training at 4k, smoke tests) and serves as the numerical oracle.
* ``attention_blockwise`` — lax.scan over KV blocks with an online-softmax
  running (max, sum, acc); memory O(S·block) instead of O(S²). This is the
  XLA-level flash attention used for the 32k/512k dry-runs (the Pallas kernel
  implements the same schedule for real TPUs; it cannot lower on the CPU
  dry-run backend).

Decode attention (one query token against a KV cache) is a separate, simpler
primitive ``decode_attention``.

All math in float32 accumulators, inputs/outputs in the model dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "repeat_kv",
    "attention_dense",
    "attention_blockwise",
    "attention",
    "decode_attention",
]

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, K, D) -> (B, S, K*n_rep, D) by repeating each KV head."""
    if n_rep == 1:
        return x
    b, s, k, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, k, n_rep, d)).reshape(
        b, s, k * n_rep, d
    )


def _mask_bias(
    q_pos: jnp.ndarray,      # (Sq,)
    k_pos: jnp.ndarray,      # (Sk,)
    causal: bool,
    window: jnp.ndarray | int,  # 0 or traced scalar => no window bound
) -> jnp.ndarray:
    """Additive mask bias (Sq, Sk) in float32."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, dtype=bool)
    if causal:
        ok &= diff >= 0
    w = jnp.asarray(window)
    ok &= jnp.where(w > 0, diff < w, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_dense(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Sk, K, D)
    v: jnp.ndarray,          # (B, Sk, K, Dv)
    *,
    causal: bool = True,
    window: jnp.ndarray | int = 0,
    q_offset: jnp.ndarray | int = 0,
) -> jnp.ndarray:
    """Reference attention; returns (B, Sq, H, Dv)."""
    b, sq, h, d = q.shape
    _, sk, kh, dv = v.shape
    k = repeat_kv(k, h // kh)
    v = repeat_kv(v, h // kh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    logits = logits + _mask_bias(q_pos, k_pos, causal, window)[None, None]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_blockwise(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Sk, K, D)
    v: jnp.ndarray,          # (B, Sk, K, Dv)
    *,
    causal: bool = True,
    window: jnp.ndarray | int = 0,
    q_offset: jnp.ndarray | int = 0,
    block_q: int = 512,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Online-softmax flash attention expressed in XLA ops.

    Requires Sq % block_q == 0 and Sk % block_k == 0 (configs pad to this).
    """
    b, sq, h, d = q.shape
    _, sk, kh, dv = v.shape
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_rep = h // kh
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    nq, nk = sq // block_q, sk // block_k
    qb = q.reshape(b, nq, block_q, h, d)
    kb = k.reshape(b, nk, block_k, h, d)
    vb = v.reshape(b, nk, block_k, h, dv)

    def q_block_body(qi, q_block):
        # q_block: (B, block_q, H, D)
        q_pos = qi * block_q + jnp.arange(block_q) + q_offset

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_block, v_block = inputs
            k_pos = ki * block_k + jnp.arange(block_k)
            logits = (
                jnp.einsum(
                    "bqhd,bkhd->bhqk", q_block, k_block,
                    preferred_element_type=jnp.float32,
                )
                * scale
            )
            logits = logits + _mask_bias(q_pos, k_pos, causal, window)[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_block.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        acc0 = jnp.zeros((b, h, block_q, dv), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0), (ks, kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)  # (B, block_q, H, Dv)

    outs = jax.lax.map(
        lambda args: q_block_body(args[0], args[1]),
        (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)),
    )  # (nq, B, block_q, H, Dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv).astype(q.dtype)


def attention(
    q, k, v, *, causal=True, window=0, q_offset=0,
    dense_threshold: int = 4096, block_q: int = 512, block_k: int = 1024,
) -> jnp.ndarray:
    """Dispatch: dense for short sequences, blockwise beyond."""
    sk = k.shape[1]
    if sk <= dense_threshold or sk % block_k or q.shape[1] % block_q:
        return attention_dense(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return attention_blockwise(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
    )


def decode_attention(
    q: jnp.ndarray,          # (B, H, D) — one new token per sequence
    k_cache: jnp.ndarray,    # (B, K, S, D) — HEAD-MAJOR cache
    v_cache: jnp.ndarray,    # (B, K, S, Dv)
    lengths: jnp.ndarray,    # (B,) valid cache lengths (the new token is at lengths-1... see note)
    *,
    window: jnp.ndarray | int = 0,
) -> jnp.ndarray:
    """Single-step attention against a (padded) head-major KV cache.

    ``lengths[b]`` = number of valid cache entries for row b **including** the
    current token's K/V (callers insert the new K/V before attending).

    The cache stays in its storage layout ``(B, K, S, D)`` — the grouped
    query heads contract against each KV head directly, so there is no
    repeat_kv materialization and no transpose anywhere on this hot path.
    Returns (B, H, Dv).
    """
    b, kh, s, d = k_cache.shape
    h = q.shape[1]
    n_rep = h // kh
    dv = v_cache.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, kh, n_rep, d)
    logits = jnp.einsum(
        "bgrd,bgsd->bgrs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                            # (B, K, n_rep, S)
    k_pos = jnp.arange(s)[None, :]                      # (1, S)
    valid = k_pos < lengths[:, None]
    w = jnp.asarray(window)
    q_pos = lengths[:, None] - 1
    valid &= jnp.where(w > 0, q_pos - k_pos < w, True)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, h, dv)
