"""Layer-level building blocks: norms, FFN variants, GQA/MLA attention
blocks and the Mamba2 mixer, each in full-sequence (train/prefill) and
single-token (decode) forms. ``model.py`` stitches these into scan-over-layer
step functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention, decode_attention
from .config import ModelConfig
from .distributed import (
    active_decode_context,
    distributed_attn_decode,
    distributed_mla_decode_absorbed,
)
from .moe import moe_ffn
from .rope import apply_rope
from .ssm import causal_conv1d, conv1d_step, ssd_chunked, ssd_decode_step

__all__ = [
    "rms_norm",
    "ffn_apply",
    "attn_full",
    "attn_decode",
    "mla_full",
    "mla_decode",
    "ssm_full",
    "ssm_decode",
]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (dense + MoE dispatch)
# ---------------------------------------------------------------------------


def ffn_apply(cfg: ModelConfig, lp: dict, x: jnp.ndarray):
    """x: (B, S, d) -> (y, aux_loss). Handles dense / MoE / Arctic residual."""
    b, s, d = x.shape
    aux = jnp.zeros((), jnp.float32)

    def dense(xf, w_gate, w_up, w_down):
        if cfg.act == "swiglu":
            z = jax.nn.silu(xf @ w_gate) * (xf @ w_up)
        elif cfg.act == "squared_relu":
            z = jnp.square(jax.nn.relu(xf @ w_up))
        else:
            z = jax.nn.gelu(xf @ w_up)
        return z @ w_down

    if cfg.is_moe:
        flat = x.reshape(b * s, d)
        out = moe_ffn(
            flat,
            lp["router"],
            lp.get("moe_gate"),
            lp["moe_up"],
            lp["moe_down"],
            k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            act=cfg.act,
        )
        y = out.y.reshape(b, s, d)
        aux = out.aux_loss
        if cfg.moe_dense_residual:  # Arctic: dense FFN in parallel
            y = y + dense(x, lp.get("w_gate"), lp["w_up"], lp["w_down"])
        return y, aux
    return dense(x, lp.get("w_gate"), lp["w_up"], lp["w_down"]), aux


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, lp: dict, x: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    return q, k, v


def attn_full(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,             # (B, S, d) — already normed
    window,                      # 0 = unbounded
    positions: jnp.ndarray,      # (S,)
):
    """Full-sequence attention. Returns (out (B,S,d), k, v)."""
    q, k, v = _qkv(cfg, lp, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, causal=cfg.causal, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    return out, k, v


def attn_decode(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,              # (B, 1, d) — normed
    k_cache: jnp.ndarray,        # (B, K, S, hd) — head-major
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,        # (B,) length INCLUDING the new token
    window,
):
    q, k, v = _qkv(cfg, lp, x)
    pos = (lengths - 1)[:, None]                     # (B,1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # (B,1,K,hd) -> (B,K,1,hd): only the single new token moves, not the cache
    k_new = k.transpose(0, 2, 1, 3)
    v_new = v.transpose(0, 2, 1, 3)

    ctx = active_decode_context()
    if ctx is not None:
        # §Perf variant: distributed flash-decode over seq-sharded caches
        o, k_cache, v_cache = distributed_attn_decode(
            q[:, 0], k_new, v_new, k_cache, v_cache, lengths, window, ctx
        )
        out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
        return out, k_cache, v_cache

    # insert new K/V at seq position lengths-1 (axis 1 of the (K,S,hd) row)
    idx = lengths - 1
    ins = lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (0, i, 0))
    k_cache = jax.vmap(ins)(k_cache, k_new, idx)
    v_cache = jax.vmap(ins)(v_cache, v_new, idx)
    o = decode_attention(q[:, 0], k_cache, v_cache, lengths, window=window)
    out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def _mla_q(cfg: ModelConfig, lp: dict, x: jnp.ndarray, positions):
    if cfg.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, lp["wq_a"]), lp["q_a_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, lp["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, lp["wq_b"])
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_kv_expand(
    cfg: ModelConfig, lp: dict, c_kv: jnp.ndarray, k_rope: jnp.ndarray,
    head_major: bool = False,
):
    """c_kv: (B,S,r), k_rope: (B,S,rope_dim) -> k,v per head.

    ``head_major=True`` emits (B,H,S,·) — the decode layout — directly from
    the expansion einsum, so the decode path never transposes the expansion.
    Same math either way; only the output axis order differs.
    """
    b, s = k_rope.shape[:2]
    spec = "bsr,rhk->bhsk" if head_major else "bsr,rhk->bshk"
    kv = jnp.einsum(spec, c_kv, lp["wkv_b"])
    k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)
    if head_major:
        k_rope_h = jnp.broadcast_to(
            k_rope[:, None, :, :], (b, cfg.n_heads, s, cfg.qk_rope_head_dim)
        )
    else:
        k_rope_h = jnp.broadcast_to(
            k_rope[:, :, None, :], (b, s, cfg.n_heads, cfg.qk_rope_head_dim)
        )
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def mla_full(cfg: ModelConfig, lp: dict, x: jnp.ndarray, window, positions):
    """Returns (out, c_kv, k_rope) — the compressed cache entries."""
    q = _mla_q(cfg, lp, x, positions)
    ckv_kr = jnp.einsum("bsd,dr->bsr", x, lp["wkv_a"])
    c_kv, k_rope = jnp.split(ckv_kr, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, lp["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    k, v = _mla_kv_expand(cfg, lp, c_kv, k_rope)
    o = attention(q, k, v, causal=cfg.causal, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    return out, c_kv, k_rope


def mla_decode_absorbed(cfg: ModelConfig, lp: dict, x, ckv_cache, krope_cache,
                        lengths, window):
    """Weight-absorbed MLA decode: attention runs in the compressed c_kv
    space, so the (B,S,H,·) expansion — and, when the rank dim is sharded,
    its per-layer all-reduce — never happens.

      scores = (q_nope · W^UK) · c_kv + q_rope · k_rope
      out    = (probs · c_kv) · W^UV · W^O

    Exactly equivalent to mla_decode (associativity of the linear maps);
    validated against it in tests. This is the §Perf 'beyond-paper'
    optimization for minicpm3-4b × decode_32k.
    """
    b = x.shape[0]
    pos = (lengths - 1)[:, None]
    q = _mla_q(cfg, lp, x, pos)                       # (B,1,H,dn+dr)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)

    ckv_kr = jnp.einsum("bsd,dr->bsr", x, lp["wkv_a"])
    c_kv, k_rope = jnp.split(ckv_kr, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, lp["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    idx = lengths - 1
    ckv_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        ckv_cache, c_kv, idx
    )
    krope_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        krope_cache, k_rope, idx
    )

    wk_b, wv_b = jnp.split(lp["wkv_b"], [cfg.qk_nope_head_dim], axis=-1)
    # absorb W^UK into the query: (B,H,dn)·(r,H,dn) -> (B,H,r)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b)
    f32 = jnp.float32
    scale = 1.0 / float(np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))

    dctx = active_decode_context()
    if dctx is not None:
        # §Perf variant: seq-sharded compressed cache + flash-decode combine
        ctx_vec, ckv_cache, krope_cache = distributed_mla_decode_absorbed(
            q_abs, q_rope[:, 0], c_kv, k_rope, ckv_cache, krope_cache,
            lengths, window, scale, dctx,
        )
        o = jnp.einsum("bhr,rhd->bhd", ctx_vec, wv_b.astype(f32)).astype(x.dtype)
        out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
        return out, ckv_cache, krope_cache

    scores = jnp.einsum(
        "bhr,bsr->bhs", q_abs.astype(f32), ckv_cache.astype(f32)
    ) + jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, 0].astype(f32), krope_cache.astype(f32)
    )
    scores = scores * scale
    s = ckv_cache.shape[1]
    k_pos = jnp.arange(s)[None, :]
    valid = k_pos < lengths[:, None]
    w = jnp.asarray(window)
    valid &= jnp.where(w > 0, (lengths[:, None] - 1 - k_pos) < w, True)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(f32))  # (B,H,r)
    o = jnp.einsum("bhr,rhd->bhd", ctx, wv_b.astype(f32)).astype(x.dtype)
    out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
    return out, ckv_cache, krope_cache


def mla_decode(cfg: ModelConfig, lp: dict, x, ckv_cache, krope_cache, lengths, window):
    """ckv_cache: (B,S,r); krope_cache: (B,S,rope_dim)."""
    pos = (lengths - 1)[:, None]
    q = _mla_q(cfg, lp, x, pos)                       # (B,1,H,hd)
    ckv_kr = jnp.einsum("bsd,dr->bsr", x, lp["wkv_a"])
    c_kv, k_rope = jnp.split(ckv_kr, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, lp["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    idx = lengths - 1
    ckv_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        ckv_cache, c_kv, idx
    )
    krope_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        krope_cache, k_rope, idx
    )
    k, v = _mla_kv_expand(cfg, lp, ckv_cache, krope_cache, head_major=True)
    o = decode_attention(q[:, 0], k, v, lengths, window=window)
    out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
    return out, ckv_cache, krope_cache


# ---------------------------------------------------------------------------
# Mamba2 mixer
# ---------------------------------------------------------------------------


def _ssm_split(cfg: ModelConfig, proj: jnp.ndarray):
    di, gn, h = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * gn], axis=-1)
    return z, xbc, dt


def ssm_full(cfg: ModelConfig, lp: dict, x: jnp.ndarray):
    """Mamba2 block over a full sequence. x: (B,S,d) normed.
    Returns (out (B,S,d), final_ssm_state, final_conv_state)."""
    b, s, _ = x.shape
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim,
    )
    proj = jnp.einsum("bsd,de->bse", x, lp["ssm_in"])
    z, xbc, dt_raw = _ssm_split(cfg, proj)
    xbc_conv = causal_conv1d(xbc, lp["conv_w"], lp["conv_b"])
    xs, Bm, Cm = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
    xs = xs.reshape(b, s, h, p)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"][None, None, :])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    # pad to a chunk multiple: padded steps get dt=0 (identity state decay,
    # zero input contribution), so states and outputs are unaffected.
    pad = (-s) % cfg.ssm_chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    if pad:
        y = y[:, :s]
        xs = xs[:, :s]
    y = y + xs * lp["D"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), lp["gnorm"])
    out = jnp.einsum("bse,ed->bsd", y, lp["ssm_out"])
    conv_state = jnp.pad(xbc, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))[
        :, s : s + cfg.conv_width - 1, :
    ]  # last W-1 pre-activation conv inputs
    return out, state, conv_state


def ssm_decode(cfg: ModelConfig, lp: dict, x: jnp.ndarray, ssm_state, conv_state):
    """One-token Mamba2 step. x: (B,1,d) normed. Returns (out, ssm_state, conv_state)."""
    b = x.shape[0]
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim,
    )
    proj = jnp.einsum("bsd,de->bse", x, lp["ssm_in"])[:, 0]
    z, xbc, dt_raw = _ssm_split(cfg, proj)
    xbc_c, conv_state = conv1d_step(conv_state, xbc, lp["conv_w"], lp["conv_b"])
    xs, Bm, Cm = jnp.split(xbc_c, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"][None, :])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, ssm_state = ssd_decode_step(
        ssm_state, xs.reshape(b, h, p), dt, A, Bm.reshape(b, g, n), Cm.reshape(b, g, n)
    )
    y = y + xs.reshape(b, h, p) * lp["D"][None, :, None]
    y = rms_norm(y.reshape(b, di) * jax.nn.silu(z), lp["gnorm"])
    out = jnp.einsum("be,ed->bd", y, lp["ssm_out"])[:, None, :]
    return out, ssm_state, conv_state
