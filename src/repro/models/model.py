"""Composable transformer/SSM language model with scan-over-layers.

Three step functions cover every (architecture × input shape) combination:

  forward(params, cfg, inputs)                 -> (logits, aux)   [train]
  prefill(params, cfg, inputs, max_len)        -> (last_logits, cache)
  decode_step(params, cfg, cache, token)       -> (logits, cache)

All layers of a model are homogeneous and stacked with a leading layer axis,
so the whole depth is one ``lax.scan`` — tiny HLO, fast dry-run compiles, and
remat applies per layer. Per-layer heterogeneity (gemma3's 5:1 local:global
pattern) is expressed as a scanned ``window`` vector, not as distinct layer
code.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sampling import first_rejection, sample_tokens, speculative_accept
from .layers import (
    attn_decode,
    attn_full,
    ffn_apply,
    mla_decode,
    mla_decode_absorbed,
    mla_full,
    rms_norm,
    ssm_decode,
    ssm_full,
)

__all__ = [
    "init_params",
    "param_shapes",
    "forward",
    "prefill",
    "decode_step",
    "decode_n",
    "draft_n",
    "verify_n",
    "init_cache",
    "window_vector",
    "Cache",
]

Cache = dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _layer_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: dict[str, tuple] = {"mixer_norm": (d,)}
    if cfg.has_attention:
        if cfg.use_mla:
            hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            if cfg.q_lora_rank:
                shapes["wq_a"] = (d, cfg.q_lora_rank)
                shapes["q_a_norm"] = (cfg.q_lora_rank,)
                shapes["wq_b"] = (cfg.q_lora_rank, cfg.n_heads, hd)
            else:
                shapes["wq_b"] = (d, cfg.n_heads, hd)
            shapes["wkv_a"] = (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            shapes["kv_a_norm"] = (cfg.kv_lora_rank,)
            shapes["wkv_b"] = (
                cfg.kv_lora_rank,
                cfg.n_heads,
                cfg.qk_nope_head_dim + cfg.v_head_dim,
            )
            shapes["wo"] = (cfg.n_heads, cfg.v_head_dim, d)
        else:
            hd = cfg.resolved_head_dim
            shapes["wq"] = (d, cfg.n_heads, hd)
            shapes["wk"] = (d, cfg.n_kv_heads, hd)
            shapes["wv"] = (d, cfg.n_kv_heads, hd)
            shapes["wo"] = (cfg.n_heads, hd, d)
            if cfg.qk_norm:
                shapes["q_norm"] = (hd,)
                shapes["k_norm"] = (hd,)
    if cfg.has_ssm:
        di = cfg.d_inner
        gn = cfg.ssm_groups * cfg.ssm_state
        h = cfg.ssm_heads
        conv_dim = di + 2 * gn
        shapes["ssm_in"] = (d, 2 * di + 2 * gn + h)
        shapes["conv_w"] = (conv_dim, cfg.conv_width)
        shapes["conv_b"] = (conv_dim,)
        shapes["A_log"] = (h,)
        shapes["D"] = (h,)
        shapes["dt_bias"] = (h,)
        shapes["gnorm"] = (di,)
        shapes["ssm_out"] = (di, d)
    if cfg.hybrid:
        shapes["attn_out_norm"] = (d,)
        shapes["ssm_out_norm"] = (d,)
    if cfg.has_ffn:
        shapes["ffn_norm"] = (d,)
        if cfg.is_moe:
            e = cfg.n_experts
            shapes["router"] = (d, e)
            if cfg.act == "swiglu":
                shapes["moe_gate"] = (e, d, f)
            shapes["moe_up"] = (e, d, f)
            shapes["moe_down"] = (e, f, d)
            if cfg.moe_dense_residual:
                if cfg.act == "swiglu":
                    shapes["w_gate"] = (d, f)
                shapes["w_up"] = (d, f)
                shapes["w_down"] = (f, d)
        else:
            if cfg.act == "swiglu":
                shapes["w_gate"] = (d, f)
            shapes["w_up"] = (d, f)
            shapes["w_down"] = (f, d)
    return shapes


def param_shapes(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    tree: dict[str, Any] = {
        "layers": {
            k: (cfg.n_layers, *s) for k, s in _layer_param_shapes(cfg).items()
        },
        "final_norm": (d,),
        "lm_head": (d, cfg.vocab),
    }
    if cfg.embed_inputs:
        tree["embed"] = (cfg.vocab, d)
    else:
        tree["in_proj"] = (d, d)  # frontend embeddings -> model width
    return tree


_NORM_KEYS = {
    "mixer_norm", "ffn_norm", "q_norm", "k_norm", "q_a_norm", "kv_a_norm",
    "gnorm", "attn_out_norm", "ssm_out_norm", "final_norm",
}
_F32_KEYS = _NORM_KEYS | {"A_log", "D", "dt_bias", "conv_b", "router"}


def _param_dtype(name: str, cfg: ModelConfig):
    return jnp.float32 if name in _F32_KEYS else jnp.dtype(cfg.dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Random init (trunc-normal 0.02 fan-in style; SSM specials per Mamba2)."""
    shapes = param_shapes(cfg)
    flat: dict[str, Any] = {}
    keys = jax.random.split(key, 64)
    ki = iter(range(64))

    def init_one(name: str, shape: tuple) -> jnp.ndarray:
        dt = _param_dtype(name, cfg)
        if name in _NORM_KEYS:
            return jnp.zeros(shape, dt)  # scales stored as (1 + s)
        if name == "A_log":
            base = jnp.tile(
                jnp.log(jnp.linspace(1.0, 16.0, shape[-1], dtype=jnp.float32)),
                (shape[0], 1) if len(shape) == 2 else (1,),
            ).reshape(shape)
            return base.astype(dt)
        if name == "D":
            return jnp.ones(shape, dt)
        if name == "dt_bias":
            return jnp.full(shape, -4.6, dt)  # softplus^-1(~0.01)
        if name == "conv_b":
            return jnp.zeros(shape, dt)
        k = keys[next(ki) % 64]
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 0.02 if name in ("embed", "lm_head") else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    for name, shape in shapes.items():
        if name == "layers":
            flat["layers"] = {k: init_one(k, s) for k, s in shape.items()}
        else:
            flat[name] = init_one(name, shape)
    return flat


# ---------------------------------------------------------------------------
# Layer meta
# ---------------------------------------------------------------------------


def window_vector(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32: 0 => unbounded attention, else sliding-window size."""
    return jnp.array(
        [0 if cfg.layer_is_global(i) else cfg.window for i in range(cfg.n_layers)],
        dtype=jnp.int32,
    )


def _embed(params: dict, cfg: ModelConfig, inputs: jnp.ndarray) -> jnp.ndarray:
    if cfg.embed_inputs:
        if cfg.embed_onehot:
            # vocab-sharded-friendly: contract a one-hot over the (sharded)
            # vocab dim instead of gathering the table (decode-scale only)
            oh = jax.nn.one_hot(inputs, params["embed"].shape[0], dtype=cfg.dtype)
            return jnp.einsum("bsv,vd->bsd", oh, params["embed"])
        return jnp.take(params["embed"], inputs, axis=0).astype(cfg.dtype)
    return jnp.einsum("bsd,de->bse", inputs.astype(cfg.dtype), params["in_proj"])


def _logits(params: dict, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full-sequence forward (train) and prefill
# ---------------------------------------------------------------------------


def _full_layer_body(cfg: ModelConfig, emit_cache: bool, seq_len: int):
    positions = jnp.arange(seq_len)

    def body(carry, xs):
        x, aux = carry
        lp, window = xs
        h = rms_norm(x, lp["mixer_norm"])
        cache_out = {}
        attn_out = ssm_out = None
        if cfg.has_attention:
            if cfg.use_mla:
                attn_out, ckv, krope = mla_full(cfg, lp, h, window, positions)
                if emit_cache:
                    cache_out = {"ckv": ckv, "krope": krope}
            else:
                attn_out, k, v = attn_full(cfg, lp, h, window, positions)
                if emit_cache:
                    cache_out = {"k": k, "v": v}
        if cfg.has_ssm:
            ssm_out, sstate, cstate = ssm_full(cfg, lp, h)
            if emit_cache:
                cache_out.update({"ssm_state": sstate, "conv_state": cstate})
        if cfg.hybrid:
            mix = 0.5 * (
                rms_norm(attn_out, lp["attn_out_norm"])
                + rms_norm(ssm_out, lp["ssm_out_norm"])
            )
        else:
            mix = attn_out if attn_out is not None else ssm_out
        x = x + mix.astype(x.dtype)
        if cfg.has_ffn:
            f, a = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
            aux = aux + a
        return (x, aux), cache_out

    return body


def _run_layers(params, cfg, h0, emit_cache: bool):
    seq_len = h0.shape[1]
    body = _full_layer_body(cfg, emit_cache, seq_len)
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), caches = jax.lax.scan(
        body,
        (h0, jnp.zeros((), jnp.float32)),
        (params["layers"], window_vector(cfg)),
    )
    return h, aux, caches


def forward(params: dict, cfg: ModelConfig, inputs: jnp.ndarray):
    """Teacher-forced full forward. Returns (logits (B,S,V) f32, aux loss)."""
    h0 = _embed(params, cfg, inputs)
    h, aux, _ = _run_layers(params, cfg, h0, emit_cache=False)
    return _logits(params, cfg, h), aux


def prefill(
    params: dict,
    cfg: ModelConfig,
    inputs: jnp.ndarray,
    max_len: int,
    lengths: Optional[jnp.ndarray] = None,
):
    """Prefill: full forward + cache construction, padded to ``max_len``.

    Returns (last_logits (B, V), cache).

    ``lengths`` (B,) optionally marks the true per-row prompt length when
    ``inputs`` is right-padded to a bucketed shape S (the serving engine pads
    prompts to a small set of bucket lengths so each distinct prompt length
    no longer triggers a fresh XLA compile). Causal masking guarantees the
    valid positions' activations and KV entries are unaffected by the pad
    tokens; last-token logits are gathered at ``lengths - 1`` and the cache
    ``lengths`` are set to the true lengths so decode masks the pad tail.
    Not valid for SSM/hybrid models (recurrent state would absorb the pads) —
    callers gate on ``cfg.has_ssm``.

    K/V caches are emitted HEAD-MAJOR ``(L, B, K, S, D)``: one transpose here
    (amortized over the whole generation) buys a zero-copy per-step decode.
    """
    b = inputs.shape[0]
    s = inputs.shape[1]
    h0 = _embed(params, cfg, inputs)
    h, _, caches = _run_layers(params, cfg, h0, emit_cache=True)
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
        last = _logits(params, cfg, h[:, -1:, :])[:, 0]
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        idx = jnp.clip(lengths - 1, 0, s - 1)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)  # (B,1,d)
        last = _logits(params, cfg, h_last)[:, 0]

    cache: Cache = {}
    pad_s = max_len - s
    for k, v in caches.items():
        if k in ("k", "v"):
            # (L, B, S, K, D) -> head-major (L, B, K, S, D), pad seq to max_len
            v = v.transpose(0, 1, 3, 2, 4)
            pads = [(0, 0)] * v.ndim
            pads[3] = (0, pad_s)
            cache[k] = jnp.pad(v, pads)
        elif k in ("ckv", "krope"):
            pads = [(0, 0)] * v.ndim
            pads[2] = (0, pad_s)  # (L, B, S, r) -> pad seq axis
            cache[k] = jnp.pad(v, pads)
        else:
            cache[k] = v
    cache["lengths"] = lengths
    return last, cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _decode_layer_body(cfg: ModelConfig, lengths: jnp.ndarray):
    def body(x, xs):
        lp, window, cache_layer = xs
        h = rms_norm(x, lp["mixer_norm"])
        new_cache = {}
        attn_out = ssm_out = None
        if cfg.has_attention:
            if cfg.use_mla:
                mla_fn = mla_decode_absorbed if cfg.mla_absorb else mla_decode
                attn_out, ckv, krope = mla_fn(
                    cfg, lp, h, cache_layer["ckv"], cache_layer["krope"], lengths, window
                )
                new_cache.update({"ckv": ckv, "krope": krope})
            else:
                attn_out, kc, vc = attn_decode(
                    cfg, lp, h, cache_layer["k"], cache_layer["v"], lengths, window
                )
                new_cache.update({"k": kc, "v": vc})
        if cfg.has_ssm:
            ssm_out, sstate, cstate = ssm_decode(
                cfg, lp, h, cache_layer["ssm_state"], cache_layer["conv_state"]
            )
            new_cache.update({"ssm_state": sstate, "conv_state": cstate})
        if cfg.hybrid:
            mix = 0.5 * (
                rms_norm(attn_out, lp["attn_out_norm"])
                + rms_norm(ssm_out, lp["ssm_out_norm"])
            )
        else:
            mix = attn_out if attn_out is not None else ssm_out
        x = x + mix.astype(x.dtype)
        if cfg.has_ffn:
            f, _ = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
        return x, new_cache

    return body


def decode_step(params: dict, cfg: ModelConfig, cache: Cache, token: jnp.ndarray):
    """One decode step. ``token``: (B,) int32 — the most recent token.

    The cache's ``lengths`` already count the prompt (and prior generated
    tokens); this step appends the new token's KV at position ``lengths``
    and returns logits for the next token, with lengths advanced by 1.

    Returns (logits (B, V) f32, new_cache).
    """
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    lengths = cache["lengths"] + 1  # include the new token
    h0 = _embed(params, cfg, token[:, None])
    layer_caches = {k: v for k, v in cache.items() if k != "lengths"}
    body = _decode_layer_body(cfg, lengths)
    h, new_caches = jax.lax.scan(
        body, h0, (params["layers"], window_vector(cfg), layer_caches)
    )
    logits = _logits(params, cfg, h)[:, 0]
    new_caches["lengths"] = lengths
    return logits, new_caches


def decode_n(
    params: dict,
    cfg: ModelConfig,
    cache: Cache,
    token: jnp.ndarray,
    num_steps: int,
    *,
    max_len: Optional[int] = None,
    active: Optional[jnp.ndarray] = None,
    sampler=None,
    keys: Optional[jnp.ndarray] = None,
):
    """Fused multi-token decode: ``num_steps`` decode_steps under one
    ``lax.scan`` so a whole chunk of tokens costs a single dispatch (and the
    caller a single host sync), instead of one per token.

    ``token``: (B,) int32 — the most recent token per row.
    Returns (tokens (num_steps, B) int32, new_cache).

    Sampling: ``sampler=None`` (or temperature 0) is greedy argmax.
    Otherwise ``sampler`` is a whole-batch ``SamplerConfig`` or — the
    serving path — per-row ``SamplerOperands`` ((B,) temperature/top-k/top-p
    runtime arrays, so heterogeneous per-request configs share one scan);
    ``keys`` carries each row's (2,) uint32 request key and step ``i`` of
    the scan draws with ``fold_in(key, lengths_after_step_i)`` — a pure
    function of (config, key, absolute position, logits), so the emitted
    stream is independent of chunk size and batch composition (see
    ``models.sampling``).

    Row-freeze semantics (both optional; when neither is given the scan body
    is the bare decode_step — no cache merge, zero extra copies):
      * ``max_len``: rows stop advancing once ``lengths`` reaches
        ``max_len - 1`` (the same guard the per-token engine loop applies),
        so a saturated row's cache is never clobbered by clamped writes.
      * ``active``: (B,) bool — rows marked inactive keep cache and lengths
        frozen (continuous-batching servers leave free slots untouched).
    Frozen rows re-emit their input token; callers discard those positions.
    A frozen row's position does not advance, so it derives (and discards)
    the same per-token key every step — no randomness is consumed.
    """
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    guard = (max_len is not None) or (active is not None)

    def body(carry, _):
        tok, c = carry
        logits, new_c = decode_step(params, cfg, c, tok)
        new_tok = sample_tokens(sampler, logits, keys, new_c["lengths"])
        if not guard:
            return (new_tok, new_c), new_tok
        ok = jnp.ones_like(tok, bool)
        if max_len is not None:
            ok &= c["lengths"] < (max_len - 1)
        if active is not None:
            ok &= active
        merged: Cache = {}
        for k, v in new_c.items():
            old = c[k]
            if k == "lengths":
                merged[k] = jnp.where(ok, v, old)
            else:  # cache arrays are (L, B, ...): broadcast over L and tails
                mask = ok.reshape((1, -1) + (1,) * (v.ndim - 2))
                merged[k] = jnp.where(mask, v, old)
        out_tok = jnp.where(ok, new_tok, tok)
        return (out_tok, merged), out_tok

    (_, cache), toks = jax.lax.scan(body, (token, cache), None, length=num_steps)
    return toks, cache


def draft_n(
    params: dict,
    cfg: ModelConfig,
    cache: Cache,
    forced: jnp.ndarray,       # (T, B) int32 teacher-forced inputs
    use_forced: jnp.ndarray,   # (T,) bool — True rows of the scan feed forced[i]
    *,
    max_len: Optional[int] = None,
    active: Optional[jnp.ndarray] = None,
    sampler=None,
    keys: Optional[jnp.ndarray] = None,
):
    """Teacher-forced-prefix fused decode: the speculative primitive.

    One ``lax.scan`` of T decode steps where step ``i`` feeds ``forced[i]``
    when ``use_forced[i]`` (teacher forcing) and the previous step's sampled
    token otherwise, emitting at every step both the sampled token AND the
    full post-mask sampling distribution (``models.sampling.sampling_probs``).
    Both speculative halves are instances of this one primitive:

      * **verify** (server): every step forced — score the k draft positions
        plus the bonus position in one dispatch (see :func:`verify_n`);
      * **draft** (device): a short forced prefix re-synchronizes the cache
        with externally-decided tokens (the verify round's correction or
        bonus), then the sampled tail drafts ahead. ``use_forced`` is a
        runtime operand, so windows with different resync lengths share one
        compile per T.

    ``use_forced[0]`` is treated as True unconditionally (the first step has
    no previous sample to feed). Sampled tokens use the stream's normal
    ``fold_in(key, position)`` draws — a draft window IS the token stream
    the device would have emitted, which is what makes matched-model
    speculative decoding bit-identical to server-only generation.

    Returns ``(toks (T, B) int32, probs (T, B, V) float32, new_cache)`` with
    lengths advanced by T (minus frozen steps). Step i's outputs score the
    position ``lengths_before + i + 1``. Frozen rows (``max_len`` /
    ``active`` guards, same semantics as :func:`decode_n`) re-emit their
    input token and report a stale distribution; callers discard them.

    Rejected for SSM/hybrid configs: callers roll the cache back to the
    accepted prefix by trimming ``lengths``, which is only sound for
    attention caches (entries past ``lengths`` are masked out and
    overwritten in place). Recurrent state cannot rewind.
    """
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    if cfg.has_ssm:
        raise ValueError(
            f"{cfg.name} has recurrent (SSM) state: speculative rollback "
            "requires a pure-attention cache"
        )
    forced = jnp.asarray(forced, jnp.int32)
    use_forced = jnp.asarray(use_forced, bool)
    guard = (max_len is not None) or (active is not None)

    def body(carry, xs):
        tok, c = carry
        f_tok, f_on = xs
        tok_in = jnp.where(f_on, f_tok, tok)
        logits, new_c = decode_step(params, cfg, c, tok_in)
        new_tok, probs = sample_tokens(
            sampler, logits, keys, new_c["lengths"], return_probs=True
        )
        if not guard:
            return (new_tok, new_c), (new_tok, probs)
        ok = jnp.ones_like(tok, bool)
        if max_len is not None:
            ok &= c["lengths"] < (max_len - 1)
        if active is not None:
            ok &= active
        merged: Cache = {}
        for k, v in new_c.items():
            old = c[k]
            if k == "lengths":
                merged[k] = jnp.where(ok, v, old)
            else:  # cache arrays are (L, B, ...): broadcast over L and tails
                mask = ok.reshape((1, -1) + (1,) * (v.ndim - 2))
                merged[k] = jnp.where(mask, v, old)
        out_tok = jnp.where(ok, new_tok, tok_in)
        return (out_tok, merged), (out_tok, probs)

    (_, cache), (toks, probs) = jax.lax.scan(
        body, (forced[0], cache), (forced, use_forced)
    )
    return toks, probs, cache


def verify_n(
    params: dict,
    cfg: ModelConfig,
    cache: Cache,
    token: jnp.ndarray,         # (B,) int32 last accepted/pending token
    draft: jnp.ndarray,         # (k, B) int32 device draft window
    device_probs: jnp.ndarray,  # (k, B, V) device sampling distributions
    *,
    max_len: Optional[int] = None,
    active: Optional[jnp.ndarray] = None,
    sampler=None,
    keys: Optional[jnp.ndarray] = None,
):
    """Server half of speculative decoding: score ``k`` draft positions in
    ONE fused dispatch and run the lossless rejection-sampling verdict.

    Teacher-forces ``[token, draft_1 .. draft_k]`` through k+1 decode steps
    (step i scores position ``lengths + i + 1``), then applies
    :func:`models.sampling.speculative_accept` per row with the stream's
    request keys, so the verdict is pure in (key, position, logits).

    Returns ``(n_acc, accept, corrections, srv_toks, probs, new_cache)``:

      * ``n_acc`` (B,) int32 — the first-rejection index: number of drafts
        to deliver before the correction.
      * ``accept`` (B, k) bool / ``corrections`` (B, k) int32 — per-position
        verdicts and residual resamples (entries past the first rejection
        are conditioned on a dead prefix; only index ``n_acc`` is usable).
      * ``srv_toks`` (k+1, B) int32 — the server's OWN ``fold_in(key, pos)``
        draws at every scored position; ``srv_toks[k]`` is the bonus token
        a fully-accepted window appends for free.
      * ``probs`` (k+1, B, V) — server sampling distributions per position.

    The new cache's lengths advance by k+1 (scratch KV for every scored
    position); the caller rolls back to ``lengths + n_acc + 1`` after the
    verdict — sound because attention cache entries past ``lengths`` are
    masked and overwritten in place (:func:`draft_n` rejects SSM configs).
    Frozen-row semantics (``max_len``/``active``) are those of
    :func:`decode_n`: frozen rows' verdicts are garbage and must be ignored.
    """
    draft = jnp.asarray(draft, jnp.int32)
    k = draft.shape[0]
    forced = jnp.concatenate([jnp.asarray(token, jnp.int32)[None], draft], axis=0)
    toks, probs, cache = draft_n(
        params, cfg, cache, forced, jnp.ones((k + 1,), bool),
        max_len=max_len, active=active, sampler=sampler, keys=keys,
    )
    # draft_i sits at position lengths_before + 1 + i (i = 0..k-1); the
    # lengths in `cache` have already advanced k+1, so recover the base
    base = cache["lengths"] - (k + 1)
    positions = base[:, None] + 1 + jnp.arange(k, dtype=jnp.int32)[None, :]
    accept, corrections = jax.vmap(speculative_accept)(
        keys, positions,
        jnp.swapaxes(draft, 0, 1),
        jnp.swapaxes(device_probs, 0, 1),
        jnp.swapaxes(probs[:k], 0, 1),
    )
    return first_rejection(accept), accept, corrections, toks, probs, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    """Zero-initialized cache pytree (for dry-run specs and fresh decode).

    K/V caches are HEAD-MAJOR ``(L, B, K, S, D)`` — the layout the flash-decode
    kernel consumes directly, so the per-step decode path never copies or
    transposes the cache.
    """
    L = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    cache: Cache = {}
    if cfg.has_attention:
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros((L, batch, max_len, cfg.qk_rope_head_dim), dt)
        else:
            hd = cfg.resolved_head_dim
            cache["k"] = jnp.zeros((L, batch, cfg.n_kv_heads, max_len, hd), dt)
            cache["v"] = jnp.zeros((L, batch, cfg.n_kv_heads, max_len, hd), dt)
    if cfg.has_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm_state"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
        cache["conv_state"] = jnp.zeros((L, batch, cfg.conv_width - 1, conv_dim), dt)
    cache["lengths"] = jnp.zeros((batch,), jnp.int32)
    return cache
