"""Checkpointing: flat-key .npz snapshots of arbitrary param/optimizer
pytrees + a JSON manifest (step, config name). No external deps.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, params: Any,
                    opt_state: Any = None, meta: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = {f"params{_SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt{_SEP}{k}": v for k, v in _flatten(opt_state).items()})
    # bfloat16 has no numpy dtype in .npz: store raw bytes + dtype tag
    packed = {}
    dtypes = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            packed[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            packed[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(path, **packed)
    manifest = {"step": step, "dtypes": dtypes, **(meta or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[5:13]) for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, params_template: Any,
                    opt_template: Any = None):
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with open(os.path.join(directory, f"ckpt_{step:08d}.json")) as f:
        manifest = json.load(f)
    raw = dict(np.load(path))
    flat = {}
    for k, v in raw.items():
        if manifest["dtypes"].get(k) == "bfloat16":
            flat[k] = v.view(jnp.bfloat16)
        else:
            flat[k] = v
    params_flat = {
        k[len(f"params{_SEP}"):]: v for k, v in flat.items()
        if k.startswith(f"params{_SEP}")
    }
    params = _unflatten_into(params_template, params_flat)
    opt_state = None
    if opt_template is not None:
        opt_flat = {
            k[len(f"opt{_SEP}"):]: v for k, v in flat.items()
            if k.startswith(f"opt{_SEP}")
        }
        opt_state = _unflatten_into(opt_template, opt_flat)
    return params, opt_state, manifest
