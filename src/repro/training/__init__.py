from .checkpoint import latest_step, load_checkpoint, save_checkpoint
from .optimizer import Optimizer, adafactor, adamw, make_optimizer
from .train_loop import TrainState, loss_fn, make_train_step, train

__all__ = [
    "latest_step", "load_checkpoint", "save_checkpoint",
    "Optimizer", "adafactor", "adamw", "make_optimizer",
    "TrainState", "loss_fn", "make_train_step", "train",
]
