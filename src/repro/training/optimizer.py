"""Optimizers in pure JAX: AdamW and Adafactor.

AdamW is the default. Adafactor (factored second moment, no momentum,
bf16-friendly) is selected for the giant archs (arctic-480b,
nemotron-4-340b): Adam's fp32 m+v for 340-480B parameters exceeds the
256-chip v5e pod's HBM (12 B/param × 480e9 ≈ 5.8 TB > 4 TB) — see
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer"]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    warmup: int = 100,
) -> Optimizer:
    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / warmup)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        lr_t = schedule(step)
        c1 = 1.0 - b1 ** (jnp.asarray(step, jnp.float32) + 1)
        c2 = 1.0 - b2 ** (jnp.asarray(step, jnp.float32) + 1)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            mhat, vhat = m_new / c1, v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decay matrices only (standard practice)
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(
    lr: float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    warmup: int = 100,
) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum.

    State per matrix (r, c): one row vector (r,) + one col vector (c,) in
    fp32 — ~0 bytes/param instead of Adam's 8.
    """

    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / warmup)

    def init(params):
        def per_param(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_param, params)

    def update(grads, state, params, step):
        lr_t = schedule(step)
        beta = 1.0 - (jnp.asarray(step, jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, eps))[..., None]
                u = g * jax.lax.rsqrt(jnp.maximum(r * vc[..., None, :], eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        leaves = jax.tree.map(
            upd, grads, state, params,
            is_leaf=lambda t: isinstance(t, dict) and ("v" in t or "vr" in t),
        )
        is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
        new_params = jax.tree.map(lambda t: t[0], leaves, is_leaf=is_pair)
        new_state = jax.tree.map(lambda t: t[1], leaves, is_leaf=is_pair)
        return new_params, new_state

    return Optimizer(init, update)


_GIANT_ARCHS = {"arctic-480b", "nemotron-4-340b"}


def make_optimizer(arch_name: str, lr: float = 3e-4) -> Optimizer:
    """Per-arch default: Adafactor for the 340-480B archs, AdamW otherwise."""
    if arch_name in _GIANT_ARCHS:
        return adafactor(lr=lr)
    return adamw(lr=lr)
