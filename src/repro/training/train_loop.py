"""Training step + loop: cross-entropy (causal LM) or masked prediction
(HuBERT encoder), MoE aux loss, microbatch gradient accumulation (lax.scan)
and per-layer remat (via the model's scan body).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig

from .optimizer import Optimizer

__all__ = ["loss_fn", "make_train_step", "train", "TrainState"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def loss_fn(params, cfg: ModelConfig, batch: dict) -> tuple[jnp.ndarray, dict]:
    """Token-level CE. ``batch``: inputs, targets[, loss_mask]."""
    logits, aux = forward(params, cfg, batch["inputs"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    if "loss_mask" in batch:  # masked prediction (HuBERT): only masked frames
        mask = batch["loss_mask"].astype(jnp.float32)
        ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    else:
        ce = nll.mean()
    total = ce + cfg.router_aux_coef * aux if cfg.is_moe else ce
    return total, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    num_microbatches: Optional[int] = None) -> Callable:
    """Returns train_step(state_tuple, batch) -> (state_tuple, metrics).

    The global batch is split into ``num_microbatches`` along axis 0 and
    gradients are accumulated with a lax.scan — constant peak activation
    memory regardless of global batch size.
    """
    n_mb = num_microbatches or cfg.num_microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, opt_state, step, batch):
        if n_mb == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n_mb == 0, (b, n_mb)
                return x.reshape(n_mb, b // n_mb, *x.shape[1:])

            mb = jax.tree.map(split, batch)
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_body(carry, mb_batch):
                g_acc, l_acc = carry
                loss, metrics, grads = grads_of(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_mb, g_acc, grads
                )
                return (g_acc, l_acc + loss / n_mb), metrics

            (grads, loss), metrics = jax.lax.scan(
                acc_body, (zero_grads, jnp.zeros((), jnp.float32)), mb
            )
            metrics = jax.tree.map(lambda m: m.mean(), metrics)

        new_params, new_opt_state = optimizer.update(grads, opt_state, params, step)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_params, new_opt_state, step + 1, out_metrics

    return train_step


def train(
    cfg: ModelConfig,
    params,
    optimizer: Optimizer,
    batches: Iterator[dict],
    n_steps: int,
    log_every: int = 10,
    log_fn: Callable[[int, dict], None] | None = None,
):
    """Simple host loop (examples / tests). Returns (params, history)."""
    step_fn = jax.jit(make_train_step(cfg, optimizer))
    opt_state = optimizer.init(params)
    step = jnp.zeros((), jnp.int32)
    history = []
    for i in range(n_steps):
        batch = next(batches)
        params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
        if i % log_every == 0 or i == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append((i, m))
            if log_fn:
                log_fn(i, m)
    return params, history
