"""Unified cost-model construction from the paper's App. E settings.

Server side: commercial API pricing (Table 8, USD per 1M tokens; input price
= prefill, output price = decode). Device side: FLOPs per token (Eq. 7-9) ×
energy_to_money.

Faithfulness note (documented deviation): the paper sets energy_to_money to
0.3 $/MFLOP (server-constrained runs) and 5 $/MFLOP (device-constrained
runs). Read literally against per-token API prices (~1e-7..1e-5 $/token),
*both* values make device energy the dominant cost by many orders of
magnitude, so Algorithm 1 would never classify a scenario as
server-constrained — the units in the paper cannot be consistent. We keep
the paper's λ for the device-constrained regime and, for the
server-constrained regime, calibrate λ down so that Algorithm 1's regime
test (max server cost > min device cost) matches the experiment's declared
intent. The *relative* Δc_decode that drives migration (Eq. 4) is preserved
per regime.
"""
from __future__ import annotations

from repro.core.cost import CostModel
from repro.core.energy import (
    BLOOM_1B1,
    BLOOM_560M,
    QWEN_05B,
    DeviceModelSpec,
    flops_per_token,
)

__all__ = ["API_PRICING_PER_M", "DEVICE_SPECS", "build_cost_model"]

# Table 8 (USD per 1M tokens, Oct 2024): (input, output)
API_PRICING_PER_M: dict[str, tuple[float, float]] = {
    "deepseek": (0.14, 0.28),
    "gpt": (0.15, 0.60),
    "llama": (0.40, 0.40),       # Hyperbolic-hosted LLaMA-3-70b
    "command": (1.25, 2.00),
}

# on-device model behind each §5.1 device profile
DEVICE_SPECS: dict[str, DeviceModelSpec] = {
    "pixel7pro-bloom1b1": BLOOM_1B1,
    "pixel7pro-bloom560m": BLOOM_560M,
    "xiaomi14-qwen05b": QWEN_05B,
}

PAPER_ENERGY_TO_MONEY = {"server": 0.3, "device": 5.0}  # $/MFLOP (App. E)


def build_cost_model(trace: str, device: str, constraint: str,
                     ref_len: int = 128) -> CostModel:
    """CostModel for (server trace, device profile, constrained endpoint).

    constraint: "server" or "device" — which endpoint's budget binds.
    ``ref_len``: context length for per-token device FLOPs (Table 6 uses
    L ∈ {32,64,128}; the App. E generation cap is 128).
    """
    if constraint not in ("server", "device"):
        raise ValueError(f"constraint must be server|device, got {constraint!r}")
    in_price, out_price = API_PRICING_PER_M[trace]
    spec = DEVICE_SPECS[device]
    prefill_mflops = flops_per_token(spec, ref_len, "prefill").total / 1e6
    decode_mflops = flops_per_token(spec, ref_len, "decode").total / 1e6

    server_prefill = in_price / 1e6
    server_decode = out_price / 1e6

    if constraint == "device":
        # paper's λ: device energy dominates -> Algorithm 1 => device-constrained
        lam = PAPER_ENERGY_TO_MONEY["device"] / 1e6
    else:
        # calibrated λ (see module docstring): device cost sits 10x *below*
        # the cheapest server price so Algorithm 1 => server-constrained.
        lam = 0.1 * min(server_prefill, server_decode) / max(prefill_mflops, decode_mflops)

    return CostModel(
        server_prefill=server_prefill,
        server_decode=server_decode,
        device_prefill_energy=prefill_mflops,
        device_decode_energy=decode_mflops,
        exchange_rate=lam,
    )
