from .costs import API_PRICING_PER_M, DEVICE_SPECS, build_cost_model
from .traces import (
    DEVICE_PROFILES,
    SERVER_TRACES,
    ServerTraceSpec,
    bursty_arrivals,
    make_requests,
    make_server_model,
    poisson_arrivals,
    sample_generation_lengths,
    sample_prompt_lengths,
)

__all__ = [
    "API_PRICING_PER_M", "DEVICE_SPECS", "build_cost_model",
    "DEVICE_PROFILES", "SERVER_TRACES", "ServerTraceSpec",
    "bursty_arrivals", "make_requests", "make_server_model",
    "poisson_arrivals", "sample_generation_lengths", "sample_prompt_lengths",
]
