"""Trace generators calibrated to the paper's §3 / §5 measurements.

This container has no internet path to OpenAI/DeepSeek/Cohere/Hyperbolic and
no Pixel/Xiaomi hardware, so we regenerate the paper's traces from the
statistics it reports:

* Server TTFT: length-independent (Table 1, |Pearson| <= 0.04), log-normal
  body with a high-load spike mixture producing the "0.3 s to several
  seconds" tails (§2.3, Fig. 2). Scale parameters per service are anchored
  to App. C Table 5 MAEs (predictor MAE ~ dispersion of the series):
  Command ≈ 0.09 s, GPT-4o-mini ≈ 0.1 s, LLaMA-3-70b ≈ 0.33 s,
  DeepSeek-V2.5 ≈ 0.4 s.
* Device endpoints: the three §5.1 device-model pairs with their measured
  prefill/decode rates (tokens/s) from Li et al. 2024b.
* Prompt lengths: Alpaca-like log-normal (the paper samples 1,000 Alpaca
  requests); §5.3 fits log-normals to lengths, which we mirror.
* Arrivals: Poisson with 30 s mean interval (§3), or DiffusionDB-like
  per-user bursty intervals (§5.3, Fig. 5).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.distributions import EmpiricalCDF
from repro.core.simulator import DeviceModel, Request, ServerModel

__all__ = [
    "ServerTraceSpec",
    "SERVER_TRACES",
    "DEVICE_PROFILES",
    "make_server_model",
    "sample_prompt_lengths",
    "sample_generation_lengths",
    "poisson_arrivals",
    "bursty_arrivals",
    "load_point_arrivals",
    "make_requests",
    "make_serving_trace",
    "make_interference_trace",
    "make_multiturn_trace",
]


@dataclasses.dataclass(frozen=True)
class ServerTraceSpec:
    """Log-normal body + spike mixture for one commercial service."""

    name: str
    mu: float          # log-mean of body (seconds)
    sigma: float       # log-std of body
    spike_prob: float  # high-load fraction (queueing episodes)
    spike_scale: float # multiplier applied during a spike
    tbt_mean: float    # mean decode TBT (packetized streaming, §3 fn.1)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        body = rng.lognormal(self.mu, self.sigma, size=n)
        spikes = rng.random(n) < self.spike_prob
        mult = np.where(spikes, self.spike_scale * (1.0 + rng.random(n)), 1.0)
        return body * mult


# Anchors: medians from §3 narrative ("TTFT spikes for GPT-4o-mini from 0.3 s
# to several seconds"), dispersions from App. C Table 5 MAE column.
SERVER_TRACES: dict[str, ServerTraceSpec] = {
    "gpt": ServerTraceSpec("gpt-4o-mini", mu=np.log(0.40), sigma=0.30,
                           spike_prob=0.06, spike_scale=5.0, tbt_mean=0.022),
    "deepseek": ServerTraceSpec("deepseek-v2.5", mu=np.log(1.30), sigma=0.28,
                                spike_prob=0.08, spike_scale=3.0, tbt_mean=0.035),
    "command": ServerTraceSpec("command", mu=np.log(0.22), sigma=0.35,
                               spike_prob=0.05, spike_scale=6.0, tbt_mean=0.025),
    "llama": ServerTraceSpec("llama3-70b", mu=np.log(0.70), sigma=0.40,
                             spike_prob=0.07, spike_scale=4.0, tbt_mean=0.030),
}

# §5.1: (device, model, prefill tok/s, decode tok/s) from Li et al. 2024b.
DEVICE_PROFILES: dict[str, DeviceModel] = {
    "pixel7pro-bloom1b1": DeviceModel(prefill_rate=31.32, decode_rate=13.93,
                                      name="Pixel 7 Pro / Bloom-1.1B"),
    "pixel7pro-bloom560m": DeviceModel(prefill_rate=51.80, decode_rate=20.14,
                                       name="Pixel 7 Pro / Bloom-560M"),
    "xiaomi14-qwen05b": DeviceModel(prefill_rate=79.90, decode_rate=21.47,
                                    name="Xiaomi 14 / Qwen-1.5-0.5B"),
}


def make_server_model(trace: str, rng: np.random.Generator, n_profile: int = 2000) -> ServerModel:
    """Build a ServerModel whose TTFT CDF is an ``n_profile``-sample profile of
    the named trace (device-side profiling, §4.2)."""
    spec = SERVER_TRACES[trace]
    samples = spec.sample(rng, n_profile)
    return ServerModel(ttft=EmpiricalCDF.from_samples(samples), tbt_mean=spec.tbt_mean)


def sample_prompt_lengths(rng: np.random.Generator, n: int,
                          mu: float = 3.3, sigma: float = 0.9,
                          max_len: int = 2048) -> np.ndarray:
    """Alpaca-like prompt lengths (median ≈ 27 tokens, right-skewed)."""
    l = rng.lognormal(mu, sigma, size=n)
    return np.clip(np.round(l), 1, max_len).astype(int)


def sample_generation_lengths(rng: np.random.Generator, n: int,
                              mu: float = 4.4, sigma: float = 0.7,
                              max_len: int = 128) -> np.ndarray:
    """Generation lengths; App. E caps generation at 128 for cost runs."""
    g = rng.lognormal(mu, sigma, size=n)
    return np.clip(np.round(g), 4, max_len).astype(int)


def poisson_arrivals(rng: np.random.Generator, n: int, mean_interval: float = 30.0) -> np.ndarray:
    """§3: Poisson arrivals with 30 s mean inter-arrival."""
    return np.cumsum(rng.exponential(mean_interval, size=n))


def bursty_arrivals(rng: np.random.Generator, n: int, n_users: int = 10,
                    within_burst: float = 4.0, between_burst: float = 120.0) -> np.ndarray:
    """DiffusionDB-like activity (§5.3): users issue bursts of requests with
    short intra-burst gaps and long idle periods; activity levels differ by
    an order of magnitude across users (stratified sampling in the paper)."""
    arrivals = []
    for u in range(n_users):
        rate = within_burst * (0.3 + 2.0 * u / max(n_users - 1, 1))
        t = 0.0
        k = n // n_users + (1 if u < n % n_users else 0)
        for _ in range(k):
            if rng.random() < 0.2:
                t += rng.exponential(between_burst)
            else:
                t += rng.exponential(rate)
            arrivals.append(t)
    return np.sort(np.asarray(arrivals))


def load_point_arrivals(rng: np.random.Generator, n: int, *,
                        service_time: float, slots: int, rho: float,
                        kind: str = "poisson") -> np.ndarray:
    """Arrival process at offered load ``rho`` for a ``slots``-wide server
    with mean per-request service time ``service_time`` (seconds): the mean
    inter-arrival is s̄ / (k·ρ), so ρ≈1 saturates the batch and ρ>1 queues —
    the §2.3 "high-load period" realized as emergent contention instead of a
    sampled delay. ``kind`` selects Poisson (§3) or DiffusionDB-like bursty
    (§5.3) arrivals; bursty traces are rescaled to the same offered load."""
    mean_interval = service_time / max(slots * rho, 1e-9)
    if kind == "poisson":
        return np.cumsum(rng.exponential(mean_interval, size=n))
    if kind == "bursty":
        arr = bursty_arrivals(rng, n)
        span = arr[-1] - arr[0] if n > 1 else 1.0
        scale = (mean_interval * max(n - 1, 1)) / max(span, 1e-9)
        return (arr - arr[0]) * scale
    raise ValueError(f"unknown arrival kind {kind!r}")


def make_serving_trace(rng: np.random.Generator, n: int, *,
                       service_time: float, slots: int, rho: float,
                       kind: str = "poisson", max_prompt: int = 48,
                       max_new: int = 16, long_fraction: float = 0.0) -> list:
    """(arrival, prompt_len, max_new) tuples for the e2e serving runner —
    Alpaca-like prompt lengths at a calibrated load point.

    ``long_fraction`` mixes in max-length prompts (the right-skewed tail the
    clipped log-normal under-represents): ragged block demand is what makes
    paged-KV admission bite, since one long prompt holds several times the
    blocks of a short one."""
    arrivals = load_point_arrivals(
        rng, n, service_time=service_time, slots=slots, rho=rho, kind=kind
    )
    lengths = np.clip(sample_prompt_lengths(rng, n), 2, max_prompt)
    if long_fraction > 0.0:
        lengths = np.where(rng.random(n) < long_fraction, max_prompt, lengths)
    return [(float(a), int(l), int(max_new)) for a, l in zip(arrivals, lengths)]


def make_cluster_load_trace(rng: np.random.Generator, n_per_replica: int, *,
                            service_time: float, slots_per_replica: int,
                            replicas: int, rho: float, kind: str = "poisson",
                            max_prompt: int = 48, max_new: int = 16) -> list:
    """(arrival, prompt_len, max_new) tuples for the replica-scaling sweep:
    request count AND offered load grow WITH the fleet (``replicas`` ×
    ``slots_per_replica`` × ``rho``) while per-replica load stays fixed, so
    a well-routed cluster should hold p99 TTFT ~flat as both scale together
    — the ``benchmarks/bench_cluster.py`` acceptance."""
    return make_serving_trace(
        rng, n_per_replica * max(1, replicas), service_time=service_time,
        slots=slots_per_replica * max(1, replicas), rho=rho, kind=kind,
        max_prompt=max_prompt, max_new=max_new,
    )


def make_interference_trace(rng: np.random.Generator, n: int, *,
                            service_time: float, slots: int, rho: float,
                            short_prompt: int = 8, short_new: int = 24,
                            long_prompt: int = 128, long_every: int = 8,
                            long_new: int = 8, jitter: float = 0.0) -> list:
    """(arrival, prompt_len, max_new) tuples for the prefill/decode
    INTERFERENCE load point: a steady background of short-prompt,
    decode-heavy requests with a max-length prompt injected every
    ``long_every``-th arrival.

    This is the workload where monolithic prefill hurts most — each long
    admission freezes every streaming row for a whole prompt's prefill, so
    the background requests' TBT series grows prompt-sized stalls. Chunked
    prefill (``BatchedServer(prefill_chunk=...)``) bounds each stall to one
    piece; ``benchmarks/bench_chunked_prefill.py`` measures the p99 TBT
    stall on exactly this trace, chunked vs monolithic.

    Arrivals are Poisson at offered load ``rho`` over the BACKGROUND
    service time (:func:`load_point_arrivals`); the long prompts ride the
    same process (deterministic every-Nth positions so the interference
    cadence is controlled, with optional ``jitter`` fraction of positions
    resampled uniformly). Background requests are decode-heavy
    (``short_new >> short_prompt``) so a long prefill has streams to stall.
    """
    if long_every < 2:
        raise ValueError(f"long_every must be >= 2 (got {long_every})")
    arrivals = load_point_arrivals(
        rng, n, service_time=service_time, slots=slots, rho=rho
    )
    is_long = np.arange(n) % long_every == long_every - 1
    if jitter > 0.0:
        flips = rng.random(n) < jitter
        is_long = np.where(flips, rng.random(n) < 1.0 / long_every, is_long)
    out = []
    for a, lng in zip(arrivals, is_long):
        if lng:
            out.append((float(a), int(long_prompt), int(long_new)))
        else:
            out.append((float(a), int(short_prompt), int(short_new)))
    return out


def make_multiturn_trace(rng: np.random.Generator, n: int, *,
                         service_time: float, slots: int, rho: float,
                         kind: str = "poisson", n_users: int = 4,
                         system_len: int = 37, turn_len: tuple = (4, 12),
                         max_new: int = 16, max_prompt: int = 96,
                         vocab: int = 1024) -> list:
    """(arrival, prompt_tokens, max_new) tuples for a multi-turn chat
    workload with a SHARED system prompt — the trace that makes a prefix
    cache bite.

    All ``n_users`` conversations open with the same ``system_len``-token
    system prompt; each turn appends the user's new message to the full
    running history (system + prior turns + prior replies), so consecutive
    prompts from one user share an ever-growing prefix and every user shares
    the system blocks. Replies are fabricated token runs (an offline trace
    cannot know the model's actual output); a driver replaying the trace
    against a live server may substitute the delivered tokens to model exact
    cache reuse. Histories that would exceed ``max_prompt`` reset to a fresh
    conversation reusing the same system prompt. Prompt token arrays are
    ``np.int32``; arrivals come from :func:`load_point_arrivals`, users
    round-robin over them, so per-user turn order follows global time."""
    arrivals = load_point_arrivals(
        rng, n, service_time=service_time, slots=slots, rho=rho, kind=kind
    )
    system = list(rng.integers(1, vocab, size=system_len))
    hist = {u: list(system) for u in range(n_users)}
    out = []
    for i, a in enumerate(arrivals):
        u = i % n_users
        turn = list(rng.integers(
            1, vocab, size=int(rng.integers(turn_len[0], turn_len[1] + 1))
        ))
        if len(hist[u]) + len(turn) > max_prompt:
            hist[u] = list(system)                  # new chat, same system
        prompt = hist[u] + turn
        out.append((float(a), np.asarray(prompt, np.int32), int(max_new)))
        reply = list(rng.integers(1, vocab, size=max_new))
        hist[u] = prompt + reply
    return out


def make_requests(rng: np.random.Generator, n: int,
                  arrivals: np.ndarray | None = None,
                  max_gen: int = 128) -> list[Request]:
    lengths = sample_prompt_lengths(rng, n)
    gens = sample_generation_lengths(rng, n, max_len=max_gen)
    arr = arrivals if arrivals is not None else poisson_arrivals(rng, n)
    return [Request(float(a), int(l), int(g)) for a, l, g in zip(arr, lengths, gens)]
