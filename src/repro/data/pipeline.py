"""Deterministic synthetic data pipeline.

Two generators:
* ``lm_batches`` — token-LM batches with a learnable structure (a noisy
  k-th order Markov chain over the vocab), so tiny models show real loss
  decrease in the training examples/tests.
* ``masked_audio_batches`` — HuBERT-style: frontend frame embeddings + mask
  + cluster-code labels (the conv/mel frontend is the documented stub).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["lm_batches", "masked_audio_batches", "zipf_prompt"]


def lm_batches(
    vocab: int, batch: int, seq: int, seed: int = 0, noise: float = 0.1,
) -> Iterator[dict]:
    """Yields {"inputs": (B,S) int32, "targets": (B,S) int32} forever.

    Sequences follow a fixed random permutation chain (x_{t+1} = perm[x_t]
    with prob 1-noise, else uniform) — a deterministic 1st-order structure a
    tiny model learns in tens of steps, with CE floor ≈ H(noise).
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab)
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        for t in range(seq):
            nxt = perm[toks[:, t]]
            noisy = rng.random(batch) < noise
            toks[:, t + 1] = np.where(noisy, rng.integers(0, vocab, batch), nxt)
        yield {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def masked_audio_batches(
    d_model: int, vocab: int, batch: int, frames: int, seed: int = 0,
    mask_prob: float = 0.3,
) -> Iterator[dict]:
    """HuBERT-style masked prediction batches.

    Frame embeddings carry the label signal (label-dependent mean + noise).
    Masked frames keep only an attenuated (0.3x), heavily-noised embedding —
    recoverable from context (labels are locally constant) plus a faint local
    cue, so the smoke-scale models can demonstrably learn the objective; the
    loss is evaluated on masked frames only, as in HuBERT.
    """
    rng = np.random.default_rng(seed)
    codebook = rng.normal(0.0, 1.0, size=(vocab, d_model)).astype(np.float32)
    while True:
        labels = rng.integers(0, vocab, size=(batch, frames))
        # smooth labels over time (audio codes are locally constant), so
        # masked frames are predictable from their neighbours
        for _ in range(4):
            labels[:, 1:] = np.where(
                rng.random((batch, frames - 1)) < 0.75, labels[:, :-1], labels[:, 1:]
            )
        embeds = codebook[labels] + 0.1 * rng.normal(size=(batch, frames, d_model))
        mask = rng.random((batch, frames)) < mask_prob
        corrupted = 0.3 * codebook[labels] + 0.5 * rng.normal(
            size=(batch, frames, d_model)
        )
        embeds = np.where(mask[..., None], corrupted, embeds).astype(np.float32)
        yield {
            "inputs": embeds,
            "targets": labels.astype(np.int32),
            "loss_mask": mask,
        }


def zipf_prompt(rng: np.random.Generator, vocab: int, length: int) -> np.ndarray:
    """Zipf-distributed token ids (natural-language-like frequencies)."""
    ranks = rng.zipf(1.3, size=length)
    return np.clip(ranks - 1, 0, vocab - 1).astype(np.int32)
