from .pipeline import lm_batches, masked_audio_batches, zipf_prompt

__all__ = ["lm_batches", "masked_audio_batches", "zipf_prompt"]
