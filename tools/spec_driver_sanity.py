"""Sanity: DiSCoServer(mode="speculative") end-to-end.

Speculative requests must deliver streams bit-identical to the same-seed
race-mode server winner, with acceptance == 1.0 at matched models.
"""
import numpy as np
import jax

from repro.configs.paper_models import TINY_SERVER
from repro.core import CostModel, DiSCoScheduler, MigrationConfig
from repro.models import init_params
from repro.models.sampling import SamplerConfig
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    InferenceEngine,
    NetworkModel,
    Request,
    ServerEndpoint,
)
from repro.serving.disco_driver import DiSCoServer

cfg = TINY_SERVER
params = init_params(cfg, jax.random.PRNGKey(0))
samp = SamplerConfig(temperature=0.8, top_k=0, top_p=1.0)


def make_disco(mode):
    server = BatchedServer(cfg, params, max_slots=4, max_len=96,
                           speculative=(mode == "speculative"))
    server.warmup(prompt_lens=(16, 48))
    dev = InferenceEngine(cfg, params, max_len=96, paged=True, kv_rows=8,
                          speculative=(mode == "speculative"))
    dev.warmup(prompt_len=16)
    cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.9,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched,
        DeviceEndpoint(dev),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(7),
        mode=mode,
    )


rng = np.random.default_rng(3)
reqs = []
t = 0.0
for n in rng.integers(6, 30, size=6):
    reqs.append(Request(rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32),
                        max_new=16, arrival=t, seed=100 + len(reqs),
                        sampler=samp))
    t += float(rng.exponential(0.15))

spec = make_disco("speculative")
res_s = spec.serve_many([r for r in reqs])
print(f"spec_requests={spec.spec_requests} fallbacks={spec.spec_fallbacks}")
stats = spec.stats()
print({k: v for k, v in stats.items() if "verify" in k or "accept" in k})

race = make_disco("race")
res_r = race.serve_many([r for r in reqs])

ok = True
for rs, rr in zip(res_s, res_r):
    same = rs.tokens == rr.tokens
    print(f"rid tokens={len(rs.tokens)} identical_to_race={same} "
          f"spec(gen={rs.generated_tokens} waste={rs.wasted_tokens} "
          f"cost={rs.cost:.4g} ttft={rs.ttft:.3f}) "
          f"race(gen={rr.generated_tokens} waste={rr.wasted_tokens} "
          f"cost={rr.cost:.4g} ttft={rr.ttft:.3f})")
    ok = ok and same
assert spec.spec_requests > 0, "no request took the speculative path"
assert ok, "speculative stream diverged from race-mode same-seed stream"
print("ALL OK")
