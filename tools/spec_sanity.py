"""Sanity: device-draft / server-verify round trip on TINY models.

Matched models + same seed must be bit-identical to server-only decode,
with every draft accepted.
"""
import numpy as np
import jax

from repro.configs.paper_models import TINY_SERVER
from repro.models import init_params
from repro.models.sampling import SamplerConfig
from repro.serving.engine import BatchedServer, InferenceEngine
from repro.serving.request import Request

cfg = TINY_SERVER
params = init_params(cfg, jax.random.PRNGKey(0))
prompt = np.arange(11, dtype=np.int32) % cfg.vocab
samp = SamplerConfig(temperature=0.8, top_k=0, top_p=1.0)
MAX_NEW = 24
SEED = 7

# --- baseline: plain server-only decode -----------------------------------
srv0 = BatchedServer(cfg, params, max_slots=2, max_len=128, decode_chunk=4)
srv0.warmup(prompt_len=len(prompt))
r0 = srv0.submit(Request(prompt, MAX_NEW, seed=SEED, sampler=samp))
ref = srv0.run_to_completion()[r0]
print("ref:", ref)

# --- speculative: device drafts, server verifies --------------------------
srv = BatchedServer(cfg, params, max_slots=2, max_len=128, decode_chunk=4,
                    speculative=True)
srv.warmup(prompt_len=len(prompt))
rid = srv.submit(Request(prompt, MAX_NEW, seed=SEED, sampler=samp),
                 verify=True)
srv.run_until(srv.clock + 1e-9)   # admission tick
ev = srv.pop_events(rid)
assert len(ev) == 1, ev
t_s = ev[0][0]
print("server prefill token:", t_s)

dev = InferenceEngine(cfg, params, max_len=128, paged=True, speculative=True)
dev.warmup(prompt_len=len(prompt))
st = dev.open_stream(Request(prompt, MAX_NEW, seed=SEED, sampler=samp))
tok0, _ = st.draft_prefill()
print("device prefill token:", tok0, "(match:", tok0 == t_s, ")")
st.force_pending(t_s)

got = [t_s]
rounds = accepted = scored = 0
while not srv.is_finished(rid):
    w = st.draft_window(4)
    if w is None:
        print("device cannot draft; aborting")
        break
    drafts, dev_probs, _ = w
    res = srv.verify_step(rid, drafts, dev_probs)
    if res is None:
        print("verify_step -> None; fallback")
        srv.end_verify(rid)
        srv.run_to_completion()
        break
    st.draft_rewind(res["accepted"], res["tokens"][-1])
    got.extend(res["tokens"])
    rounds += 1
    accepted += res["accepted"]
    scored += res["k"]
    for tok, _t in srv.pop_events(rid):
        pass

print(f"rounds={rounds} accepted={accepted}/{scored}")
print("got:", got)
print("bit-identical:", got == ref)
print("pool_stats:", {k: v for k, v in srv.pool_stats().items()
                      if "verify" in k or "accept" in k or "draft" in k})
assert got == ref, "speculative stream diverged from server-only"
assert accepted == scored, "matched models must accept every draft"

# --- corrupted drafts must still be bit-identical (lossless) -------------
srv2 = BatchedServer(cfg, params, max_slots=2, max_len=128, decode_chunk=4,
                     speculative=True)
srv2.warmup(prompt_len=len(prompt))
rid2 = srv2.submit(Request(prompt, MAX_NEW, seed=SEED, sampler=samp),
                   verify=True)
srv2.run_until(srv2.clock + 1e-9)
t_s2 = srv2.pop_events(rid2)[0][0]
dev2 = InferenceEngine(cfg, params, max_len=128, paged=True, speculative=True)
dev2.warmup(prompt_len=len(prompt))
st2 = dev2.open_stream(Request(prompt, MAX_NEW, seed=SEED, sampler=samp))
st2.draft_prefill()
st2.force_pending(t_s2)
got2 = [t_s2]
rng = np.random.default_rng(0)
acc2 = sc2 = 0
while not srv2.is_finished(rid2):
    w = st2.draft_window(4)
    if w is None:
        break
    drafts, dev_probs, _ = w
    # corrupt the middle draft half the time: rejection path must engage
    if len(drafts) >= 2 and rng.random() < 0.5:
        drafts = list(drafts)
        drafts[1] = int((drafts[1] + 1) % cfg.vocab)
    res = srv2.verify_step(rid2, drafts, dev_probs)
    if res is None:
        srv2.end_verify(rid2)
        srv2.run_to_completion()
        break
    st2.draft_rewind(res["accepted"], res["tokens"][-1])
    got2.extend(res["tokens"])
    acc2 += res["accepted"]
    sc2 += res["k"]
print(f"corrupted run: accepted={acc2}/{sc2}")
print("corrupted-but-lossless bit-identical:", got2 == ref)
print("got2:", got2)
