#!/usr/bin/env python
"""TTFT attribution report for a serving trace.

Reads a Chrome trace-event JSON produced by ``repro.serving.telemetry.Tracer``
(e.g. ``bench_e2e_serving --trace-out trace.json``) and prints:

* a per-request TTFT attribution table — how much of each request's
  time-to-first-token went to server queueing, prefill compute, network
  propagation, draft-verdict stalls, and (on a disaggregated cluster
  trace) the prefill→decode KV hand-off — with the p99-TTFT request
  marked.  The ``replica`` column attributes each server-side stream to
  the replica/worker lane that served its prefill (the stream decodes on
  the sibling decode worker; ``-`` on monolithic traces).
  The ``stall_ms`` column is post-first-token decode interference: other
  requests' prefill work overlapping this request's streaming phase. A
  monolithic server shows prompt-sized stalls here under mixed-length load;
  chunked prefill (``prefill_chunk``) bounds each to one piece;
* ASCII waterfalls for the tail (slowest-TTFT) requests, showing where the
  first token's latency actually accrued on the virtual timeline.

``--check`` turns the report into a CI gate: the trace must be schema-valid
(``validate_trace`` returns no problems), contain at least one complete span
and one request record, and every request record must close.  Exits non-zero
on any violation.

    PYTHONPATH=src python tools/trace_report.py trace.json [--check] [--tail N]
"""
from __future__ import annotations

import argparse
import json
import sys

try:
    from repro.serving.telemetry import (
        request_records,
        trace_spans,
        ttft_attribution,
        validate_trace,
    )
except ImportError:  # running without PYTHONPATH=src
    import pathlib

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from repro.serving.telemetry import (
        request_records,
        trace_spans,
        ttft_attribution,
        validate_trace,
    )

_COMPONENTS = ("queue_s", "prefill_s", "network_s", "draft_stall_s",
               "handoff_s")
_BAR_WIDTH = 48


def _fmt_ms(v) -> str:
    return "-" if v is None else f"{v * 1e3:9.2f}"


def _p99_rid(rows: list[dict]):
    timed = [r for r in rows if r["ttft_s"] is not None]
    if not timed:
        return None
    timed.sort(key=lambda r: r["ttft_s"])
    idx = min(len(timed) - 1, int(round(0.99 * (len(timed) - 1))))
    return timed[idx]["rid"]


def print_attribution(rows: list[dict]) -> None:
    p99 = _p99_rid(rows)
    print(
        f"{'rid':>4} {'ttft_ms':>9} {'queue_ms':>9} {'prefill_ms':>10} "
        f"{'network_ms':>10} {'draft_ms':>9} {'handoff_ms':>10} "
        f"{'stall_ms':>9} {'replica':>8} {'winner':>8} {'outcome':>10}"
    )
    for r in rows:
        mark = "  <-- p99" if r["rid"] == p99 else ""
        print(
            f"{r['rid']:>4} {_fmt_ms(r['ttft_s']):>9} {_fmt_ms(r['queue_s']):>9} "
            f"{_fmt_ms(r['prefill_s']):>10} {_fmt_ms(r['network_s']):>10} "
            f"{_fmt_ms(r['draft_stall_s']):>9} "
            f"{_fmt_ms(r.get('handoff_s', 0.0)):>10} "
            f"{_fmt_ms(r.get('decode_stall_s', 0.0)):>9} "
            f"{str(r.get('replica') or '-'):>8} "
            f"{str(r['winner'] or '-'):>8} {str(r['outcome'] or '-'):>10}{mark}"
        )


def print_waterfalls(rows: list[dict], tail: int) -> None:
    timed = sorted(
        (r for r in rows if r["ttft_s"] is not None),
        key=lambda r: r["ttft_s"],
        reverse=True,
    )[:tail]
    if not timed:
        return
    scale = max(r["ttft_s"] for r in timed) or 1e-9
    print(f"\ntail waterfalls (slowest {len(timed)} by TTFT):")
    glyphs = {"queue_s": "q", "prefill_s": "p", "network_s": "n",
              "draft_stall_s": "d", "handoff_s": "h"}
    for r in timed:
        accounted = sum(r.get(c, 0.0) for c in _COMPONENTS)
        other = max(0.0, r["ttft_s"] - accounted)
        bar = ""
        for comp in _COMPONENTS + ("other",):
            v = other if comp == "other" else r.get(comp, 0.0)
            bar += glyphs.get(comp, ".") * int(round(v / scale * _BAR_WIDTH))
        # components may overlap in wall-time (network in flight during
        # prefill), so the stacked bar can exceed the TTFT width — clip it
        bar = bar[:_BAR_WIDTH]
        print(f"  req{r['rid']:<4} |{bar:<{_BAR_WIDTH}}| "
              f"ttft={r['ttft_s'] * 1e3:.2f}ms")
    print("  legend: q=queue p=prefill n=network d=draft-stall "
          "h=kv-handoff .=other")
    print("  (stall_ms in the table is post-TTFT decode interference — "
          "not part of the TTFT waterfall)")


def check(trace: dict, rows: list[dict]) -> list[str]:
    failures = list(validate_trace(trace))
    if not trace_spans(trace):
        failures.append("trace has no complete (ph=X) spans")
    recs = request_records(trace)
    if not recs:
        failures.append("trace has no driver request records (cat=request)")
    for rid, rec in recs.items():
        if rec["end"] is None:
            failures.append(f"request {rid}: async span never closed")
    if not rows:
        failures.append("ttft_attribution produced no rows")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--tail", type=int, default=3,
                    help="number of slowest-TTFT waterfalls to print")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: exit non-zero unless the trace is "
                         "schema-valid with non-empty spans and records")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        trace = json.load(f)
    rows = ttft_attribution(trace)

    n_events = len(trace.get("traceEvents", []))
    print(f"trace: {args.trace} ({n_events} events, {len(rows)} requests)")
    meta = trace.get("otherData")
    if meta:
        keys = ", ".join(f"{k}={v}" for k, v in meta.items()
                         if not isinstance(v, (dict, list)))
        if keys:
            print(f"metadata: {keys}")
    print()
    print_attribution(rows)
    print_waterfalls(rows, args.tail)

    if args.check:
        failures = check(trace, rows)
        if failures:
            print("\ntrace check FAILED:", file=sys.stderr)
            for p in failures:
                print(f"  {p}", file=sys.stderr)
            return 1
        print(f"\ntrace check OK: {n_events} events, {len(rows)} request "
              "records, schema valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
